"""Arrival-generator coverage: monotonicity, determinism under a fixed
seed, rate, and burst structure (the paper's §5 arrival-shaping lever
depends on these generators being exactly reproducible)."""
import numpy as np
import pytest

from repro.serving import (burst_arrivals, fixed_arrivals,
                           poisson_arrivals, uniform_random_arrivals)

GENERATORS = {
    "fixed": lambda n, seed: fixed_arrivals(n, 0.05),
    "uniform": lambda n, seed: uniform_random_arrivals(
        n, 0.01, 0.2, seed=seed),
    "poisson": lambda n, seed: poisson_arrivals(
        n, rate_per_s=8.0, seed=seed),
    "burst": lambda n, seed: burst_arrivals(n, 7, 0.5),
}


@pytest.mark.parametrize("name", sorted(GENERATORS))
class TestAllGenerators:
    def test_monotone_nondecreasing(self, name):
        a = GENERATORS[name](200, seed=3)
        assert all(x <= y for x, y in zip(a, a[1:]))

    def test_length_and_start(self, name):
        a = GENERATORS[name](64, seed=1)
        assert len(a) == 64
        assert a[0] == pytest.approx(0.0)

    def test_deterministic_under_seed(self, name):
        a = GENERATORS[name](100, seed=7)
        b = GENERATORS[name](100, seed=7)
        assert a == b


class TestSeedSensitivity:
    @pytest.mark.parametrize("gen", ["uniform", "poisson"])
    def test_different_seeds_differ(self, gen):
        a = GENERATORS[gen](50, seed=0)
        b = GENERATORS[gen](50, seed=1)
        assert a != b


class TestStructure:
    def test_fixed_spacing_exact(self):
        a = fixed_arrivals(10, 0.25, start=1.0)
        gaps = np.diff(a)
        assert np.allclose(gaps, 0.25)
        assert a[0] == 1.0

    def test_burst_structure(self):
        a = burst_arrivals(10, burst_size=3, burst_gap_s=2.0)
        # bursts of exactly burst_size share one timestamp ...
        assert a == [0.0, 0.0, 0.0, 2.0, 2.0, 2.0, 4.0, 4.0, 4.0, 6.0]
        # ... separated by exactly burst_gap_s
        uniq = sorted(set(a))
        assert np.allclose(np.diff(uniq), 2.0)

    def test_poisson_mean_rate(self):
        a = poisson_arrivals(4000, rate_per_s=20.0, seed=2)
        assert a[-1] == pytest.approx(4000 / 20.0, rel=0.15)

    def test_uniform_gap_bounds(self):
        a = uniform_random_arrivals(500, 0.1, 0.3, seed=5)
        gaps = np.diff(a)
        assert gaps.min() >= 0.1 - 1e-12
        assert gaps.max() <= 0.3 + 1e-12

    def test_start_offset(self):
        for gen in ("uniform", "poisson"):
            fn = {"uniform": uniform_random_arrivals,
                  "poisson": poisson_arrivals}[gen]
            kw = {"seed": 4, "start": 3.0}
            a = (fn(20, 0.1, 0.2, **kw) if gen == "uniform"
                 else fn(20, rate_per_s=5.0, **kw))
            assert a[0] == pytest.approx(3.0)
