"""Pallas kernel tests: shape/dtype sweeps, interpret=True vs the
pure-jnp ref.py oracle (assignment requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import quantize_int8, quantize_nf4
from repro.kernels.quant_matmul.kernel import (int8_matmul_pallas,
                                               nf4_matmul_pallas)
from repro.kernels.quant_matmul import ops as qops
from repro.kernels.quant_matmul.ref import (int8_matmul_ref,
                                            nf4_matmul_ref)
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


def _rand(shape, seed, dtype=jnp.float32, scale=0.3):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    return x.astype(dtype)


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n,bm,bk,bn", [
        (32, 128, 64, 32, 64, 64),
        (64, 256, 128, 32, 128, 64),
        (128, 512, 256, 64, 256, 128),
        (8, 128, 128, 8, 128, 128),
    ])
    def test_int8_shapes(self, m, k, n, bm, bk, bn):
        x = _rand((m, k), 0)
        w = _rand((k, n), 1, scale=0.05)
        q = quantize_int8(w)
        out = int8_matmul_pallas(x, q.codes, q.scale, bm=bm, bn=bn, bk=bk,
                                 compute_dtype=jnp.float32)
        ref = int8_matmul_ref(x, q.codes, q.scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                           (jnp.bfloat16, 2e-2)])
    def test_int8_dtypes(self, dtype, tol):
        x = _rand((32, 256), 0)
        w = _rand((256, 128), 1, scale=0.05)
        q = quantize_int8(w)
        out = int8_matmul_pallas(x, q.codes, q.scale, bm=32, bn=128,
                                 bk=128, compute_dtype=dtype)
        ref = int8_matmul_ref(x, q.codes, q.scale)
        rel = np.abs(np.asarray(out, np.float32) - np.asarray(ref)).max() \
            / (np.abs(np.asarray(ref)).max() + 1e-9)
        assert rel < tol

    @pytest.mark.parametrize("block", [16, 32, 64])
    @pytest.mark.parametrize("m,k,n", [(32, 128, 64), (64, 256, 128)])
    def test_nf4_shapes(self, block, m, k, n):
        x = _rand((m, k), 0)
        w = _rand((k, n), 1, scale=0.05)
        q = quantize_nf4(w, block)
        out = nf4_matmul_pallas(x, q.packed, q.absmax, bm=m, bn=n,
                                bk=min(128, k), compute_dtype=jnp.float32)
        ref = nf4_matmul_ref(x, q.packed, q.absmax)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_wrapper_with_outliers(self):
        x = _rand((4, 16, 128), 0, scale=1.0)        # 3-D input
        w = np.array(_rand((128, 64), 1, scale=0.05))
        w[3] *= 50                                   # force an outlier row
        w = jnp.asarray(w)
        q = quantize_int8(w, outlier_fraction=0.02)
        out = qops.int8_matmul_kernel(x, q, compute_dtype=jnp.float32)
        ref = jnp.einsum("bsk,kn->bsn", x, w)
        rel = float(jnp.max(jnp.abs(out - ref))
                    / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert out.shape == (4, 16, 64)
        assert rel < 0.02

    def test_nf4_ops_wrapper(self):
        x = _rand((2, 8, 128), 0, scale=1.0)
        w = _rand((128, 64), 1, scale=0.05)
        q = quantize_nf4(w, 64)
        out = qops.nf4_matmul_kernel(x, q, compute_dtype=jnp.float32)
        ref = nf4_matmul_ref(x.reshape(-1, 128), q.packed, q.absmax)
        np.testing.assert_allclose(np.asarray(out).reshape(-1, 64),
                                   np.asarray(ref), rtol=1e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("S,bq,bkv", [(128, 64, 64), (256, 64, 128),
                                          (256, 256, 256)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shapes_causal(self, S, bq, bkv, causal):
        B, H, Kv, d = 2, 4, 2, 64
        q = _rand((B, S, H, d), 0, scale=1.0)
        k = _rand((B, S, Kv, d), 1, scale=1.0)
        v = _rand((B, S, Kv, d), 2, scale=1.0)
        out = flash_attention_pallas(q, k, v, causal=causal, bq=bq,
                                     bkv=bkv)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 100])
    def test_sliding_window(self, window):
        B, S, H, Kv, d = 1, 256, 4, 4, 32
        q = _rand((B, S, H, d), 0, scale=1.0)
        k = _rand((B, S, Kv, d), 1, scale=1.0)
        v = _rand((B, S, Kv, d), 2, scale=1.0)
        out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     bq=64, bkv=64)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_gqa_groups(self):
        """H != Kv exercises the kv index_map group arithmetic."""
        B, S, H, Kv, d = 2, 128, 8, 2, 32
        q = _rand((B, S, H, d), 0, scale=1.0)
        k = _rand((B, S, Kv, d), 1, scale=1.0)
        v = _rand((B, S, Kv, d), 2, scale=1.0)
        out = flash_attention_pallas(q, k, v, bq=64, bkv=64)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        B, S, H, Kv, d = 1, 128, 4, 2, 64
        q = _rand((B, S, H, d), 0, jnp.bfloat16, 1.0)
        k = _rand((B, S, Kv, d), 1, jnp.bfloat16, 1.0)
        v = _rand((B, S, Kv, d), 2, jnp.bfloat16, 1.0)
        out = flash_attention_pallas(q, k, v, bq=64, bkv=64)
        ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
        assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) < 0.05


class TestPagedAttention:
    def _pool(self, n_pool, page, Kv, d, seed=0):
        return (_rand((n_pool, page, Kv, d), seed, scale=1.0),
                _rand((n_pool, page, Kv, d), seed + 1, scale=1.0))

    @pytest.mark.parametrize("page", [16, 32, 128])
    def test_page_sizes(self, page):
        n_pool, B, H, Kv, d = 12, 2, 8, 2, 64
        kp, vp = self._pool(n_pool, page, Kv, d)
        q = _rand((B, H, d), 5, scale=1.0)
        pt = jnp.array([[0, 1, 2], [3, 4, -1]], jnp.int32)
        sl = jnp.array([2 * page + 3, page + 1], jnp.int32)
        out = paged_attention_pallas(q, kp, vp, pt, sl)
        ref = paged_attention_ref(q, kp, vp, pt, sl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_contiguous_attention(self):
        """Paged result == ordinary decode attention over the gathered
        cache (cross-oracle check against flash ref)."""
        page, n_pool, B, H, Kv, d = 32, 8, 2, 4, 4, 32
        kp, vp = self._pool(n_pool, page, Kv, d)
        q = _rand((B, H, d), 9, scale=1.0)
        pt = jnp.array([[2, 0], [5, -1]], jnp.int32)
        sl = jnp.array([50, 20], jnp.int32)
        out = paged_attention_pallas(q, kp, vp, pt, sl)
        # build contiguous caches and use the flash oracle (q len 1)
        for b in range(B):
            pages = [p for p in np.asarray(pt[b]) if p >= 0]
            kc = jnp.concatenate([kp[p] for p in pages], 0)[:int(sl[b])]
            vc = jnp.concatenate([vp[p] for p in pages], 0)[:int(sl[b])]
            ref = attention_ref(q[b:b + 1, None], kc[None], vc[None],
                                causal=False)
            np.testing.assert_allclose(np.asarray(out[b]),
                                       np.asarray(ref[0, 0]),
                                       rtol=2e-5, atol=2e-5)

    def test_single_page_and_full_pool(self):
        page, n_pool, B, H, Kv, d = 16, 4, 1, 2, 1, 32
        kp, vp = self._pool(n_pool, page, Kv, d)
        q = _rand((B, H, d), 3, scale=1.0)
        pt = jnp.array([[1]], jnp.int32)
        sl = jnp.array([7], jnp.int32)
        out = paged_attention_pallas(q, kp, vp, pt, sl)
        ref = paged_attention_ref(q, kp, vp, pt, sl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
