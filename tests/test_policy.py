"""Batch-formation policy tests: deprecation shims, SlotCount golden
parity, spec-axis validation (with speccache hash regression), token-
budget and length-sorted properties, chunked-prefill accounting,
macro-step parity for every policy, and disaggregated prefill/decode
serving."""
import glob
import json
import math
import os
import warnings

import pytest

from repro.api import ExperimentSpec
from repro.batching import ContinuousBatcher
from repro.batching.policy import (BATCH_POLICIES, ChunkedPrefillPolicy,
                                   LengthSortedPolicy, SlotCountPolicy,
                                   TokenBudgetPolicy, make_batch_policy)
from repro.configs.paper_zoo import PAPER_MODELS
from repro.serving.arrival import fixed_arrivals, paper_requests
from repro.serving.cluster import make_cluster
from repro.serving.engine import ServeEngine
from repro.serving.requests import Request

LLAMA8B = PAPER_MODELS["llama-3.1-8b"]
DATA = os.path.join(os.path.dirname(__file__), "data")
SPECCACHE = os.path.join(os.path.dirname(__file__), os.pardir,
                         "experiments", "bench", "speccache")


def _reqs(n=24, seed=0, prompt_range=(200, 4000), output_range=(10, 120),
          gap=0.0):
    return paper_requests(n, fixed_arrivals(n, gap), seed=seed,
                          prompt_range=prompt_range,
                          output_range=output_range)


def _fixed_reqs(plens, out=20):
    return [Request(req_id=i, prompt=None, prompt_len=p,
                    max_new_tokens=out, arrival_time=0.0)
            for i, p in enumerate(plens)]


def _report_sig(rep):
    return (rep.total_energy_j, rep.busy_energy_j, rep.wall_time_s,
            [r.t_done for r in rep.requests],
            [r.ttft for r in rep.requests],
            [r.energy_j for r in rep.requests])


# ---------------------------------------------------------------------------
# legacy kwargs: removed for good — batch_policy= is the only spelling
# ---------------------------------------------------------------------------
class TestLegacyKwargsRemoved:
    @pytest.mark.parametrize("kwargs", [
        dict(max_batch=8),
        dict(max_prefill_batch=4),
        dict(bucket_prefill=True),
    ])
    def test_removed_kwargs_raise_type_error(self, kwargs):
        with pytest.raises(TypeError):
            ServeEngine(LLAMA8B, **kwargs)

    def test_no_deprecation_warnings_remain(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ServeEngine(LLAMA8B)
            ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy(max_batch=8))

    def test_policy_conflicts_raise(self):
        with pytest.raises(ValueError, match="mode='continuous'"):
            ServeEngine(LLAMA8B, mode="sequential",
                        batch_policy=TokenBudgetPolicy(token_budget=4096))


# ---------------------------------------------------------------------------
# SlotCountPolicy parity: the refactor must not move a single bit
# ---------------------------------------------------------------------------
class TestSlotCountParity:
    with open(os.path.join(DATA, "golden_pre_refactor.json")) as f:
        GOLDEN = json.load(f)["records"]

    @pytest.mark.parametrize("name", sorted(GOLDEN))
    def test_golden_records_still_reproduce(self, name):
        rec = self.GOLDEN[name]
        spec = ExperimentSpec.from_dict(rec["spec"])
        assert spec.spec_hash() == rec["spec_hash"]
        assert spec.run().to_json() == rec["result"]

    def test_explicit_slot_count_matches_default(self):
        default = ServeEngine(LLAMA8B)
        explicit = ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy())
        assert _report_sig(default.run(_reqs(gap=0.2))) \
            == _report_sig(explicit.run(_reqs(gap=0.2)))


# ---------------------------------------------------------------------------
# spec axes: validation + serialization stability
# ---------------------------------------------------------------------------
class TestSpecAxes:
    @pytest.mark.parametrize("changes, match", [
        (dict(batch_policy="nope"), "unknown batch_policy"),
        (dict(batch_policy="token_budget"), "token_budget is required"),
        (dict(batch_policy="token_budget",
              policy_params={"token_budget": -5}),
         "token_budget must be >= 1"),
        (dict(batch_policy="chunked_prefill",
              policy_params={"chunk_tokens": 0}),
         "chunk_tokens must be >= 1"),
        (dict(batch_policy="length_sorted",
              policy_params={"window": 0}), "window must be >= 1"),
        (dict(policy_params={"max_batch": 4}),
         "policy_params may not set"),
        (dict(batch_policy="length_sorted",
              policy_params={"bogus": 1}), "unknown policy_params"),
        (dict(batch_policy="length_sorted", mode="sequential"),
         "mode='continuous'"),
        (dict(batch_policy="length_sorted", pipeline="profile"),
         "pipeline='serve'"),
        (dict(disaggregate=1), "replicas >= 2"),
        (dict(disaggregate=2, replicas=2), "no decode"),
        (dict(disaggregate=-1), ">= 0"),
    ])
    def test_rejects(self, changes, match):
        with pytest.raises(ValueError, match=match):
            ExperimentSpec(**changes)

    def test_registry_and_factory(self):
        assert BATCH_POLICIES == ("slot_count", "token_budget",
                                  "length_sorted", "chunked_prefill")
        with pytest.raises(ValueError, match="unknown batch policy"):
            make_batch_policy("nope")
        pol = make_batch_policy("token_budget", token_budget=4096,
                                max_batch=8)
        assert isinstance(pol, TokenBudgetPolicy)
        assert (pol.token_budget, pol.max_batch) == (4096, 8)

    def test_round_trip_and_hash(self):
        spec = ExperimentSpec(batch_policy="token_budget",
                              policy_params={"token_budget": 8192},
                              n_requests=8)
        again = ExperimentSpec.from_json(spec.to_json())
        assert again == spec and again.spec_hash() == spec.spec_hash()
        assert spec.spec_hash() != ExperimentSpec(n_requests=8).spec_hash()

    def test_default_spec_json_has_no_new_keys(self):
        d = ExperimentSpec(n_requests=8).to_dict()
        for key in ("batch_policy", "policy_params", "disaggregate"):
            assert key not in d

    def test_speccache_hashes_unchanged(self):
        blobs = sorted(glob.glob(os.path.join(SPECCACHE, "*.json")))
        if not blobs:            # fresh checkout: cache not built yet
            pytest.skip("no memoized sweep blobs to regress against")
        for path in blobs:
            with open(path) as f:
                blob = json.load(f)
            spec = ExperimentSpec.from_dict(blob["spec"])
            stem = os.path.splitext(os.path.basename(path))[0]
            assert spec.spec_hash() == stem, \
                f"spec hash drifted for {os.path.basename(path)}"

    def test_formation_fields_round_trip(self):
        from repro.api import RunResult
        res = ExperimentSpec(batch_policy="length_sorted",
                             n_requests=8).run()
        d = res.to_dict()
        assert "prefill_padding_fraction" in d and "n_handoffs" in d
        assert RunResult.from_json(res.to_json()).to_json() \
            == res.to_json()
        plain = ExperimentSpec(n_requests=8).run().to_dict()
        assert "prefill_padding_fraction" not in plain


# ---------------------------------------------------------------------------
# policy properties (driven through the batcher, no engine clock)
# ---------------------------------------------------------------------------
class TestTokenBudgetProperty:
    @pytest.mark.parametrize("seed", range(4))
    def test_committed_tokens_never_exceed_budget(self, seed):
        budget = 6000
        pol = TokenBudgetPolicy(token_budget=budget, max_batch=32,
                                max_prefill_batch=8, bucket_prefill=False)
        b = ContinuousBatcher(policy=pol, kv_pages=1 << 14)
        reqs = _reqs(48, seed=seed, prompt_range=(50, 4000),
                     output_range=(5, 120))
        for r in reqs:                   # every request fits the budget
            assert r.prompt_len + r.max_new_tokens <= budget
            b.admit(r)
        admitted = 0
        while b.n_waiting or b.n_live:
            plan = pol.schedule_prefill(b, 0.0)
            if plan is not None:
                admitted += len(plan.picks)
                for slot, _ in plan.picks:
                    b.complete_prefill(slot)
            assert b.live_committed_tokens <= budget
            for slot in list(b.step_decode_bookkeeping()):
                r = b.slots[slot].request
                r.tokens_generated += 1
                if r.tokens_generated >= r.max_new_tokens:
                    b.finish(slot)
        assert admitted == len(reqs)


class TestLengthSortedProperty:
    @pytest.mark.parametrize("seed", range(4))
    def test_padding_never_worse_than_fifo(self, seed):
        pol = LengthSortedPolicy(max_batch=64, max_prefill_batch=8,
                                 window=16, patience=10 ** 9)
        b = ContinuousBatcher(policy=pol, kv_pages=1 << 14)
        for r in _reqs(64, seed=seed, prompt_range=(50, 4000)):
            b.admit(r)
        while b.n_waiting:
            cands = b.waiting[:pol.window]
            plan = pol.schedule_prefill(b, 0.0)
            assert plan is not None
            k = len(plan.picks)
            fifo = cands[:k]
            fifo_cost = (k * max(r.prompt_len for r in fifo)
                         - sum(r.prompt_len for r in fifo))
            cost = (k * plan.pad_len
                    - sum(r.prompt_len for _, r in plan.picks))
            assert cost <= fifo_cost
            for slot, _ in plan.picks:   # drain so slots free up
                b.complete_prefill(slot)
                b.finish(slot)

    def test_patience_bounds_head_starvation(self):
        pol = LengthSortedPolicy(max_batch=64, max_prefill_batch=2,
                                 window=8, patience=1)
        b = ContinuousBatcher(policy=pol, kv_pages=1 << 14)
        # long head followed by a stream of well-matched short pairs:
        # an unbounded sorter would never pick the head
        for r in _fixed_reqs([4000] + [100] * 8):
            b.admit(r)
        batches = []
        while b.n_waiting:
            plan = pol.schedule_prefill(b, 0.0)
            batches.append([r.req_id for _, r in plan.picks])
            for slot, _ in plan.picks:
                b.complete_prefill(slot)
                b.finish(slot)
        picked_in = next(i for i, ids in enumerate(batches) if 0 in ids)
        assert picked_in <= pol.patience


# ---------------------------------------------------------------------------
# conservation: tokens are neither lost nor double-counted
# ---------------------------------------------------------------------------
class TestConservation:
    @pytest.mark.parametrize("policy", [
        SlotCountPolicy(max_batch=8),
        TokenBudgetPolicy(token_budget=8192, max_batch=8),
        LengthSortedPolicy(max_batch=8),
        ChunkedPrefillPolicy(chunk_tokens=256, max_batch=8),
    ], ids=lambda p: p.name)
    def test_outstanding_plus_done_is_constant(self, policy):
        reqs = _reqs(16, prompt_range=(100, 2000), output_range=(5, 60))
        total = sum(r.prompt_len + r.max_new_tokens for r in reqs)
        eng = ServeEngine(LLAMA8B, batch_policy=policy)
        eng.stream_start()
        for r in reqs:
            eng.stream_submit(r)
        while eng.stream_can_step():
            eng.stream_step()
            done = sum(r.prefilled_tokens + r.tokens_generated
                       for r in reqs)
            assert eng.stream_outstanding_work() + done == total
        rep = eng.stream_report()
        assert rep.n == len(reqs)
        assert eng.stream_outstanding_work() == 0


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------
class TestChunkedPrefill:
    def test_chunk_count_and_completion(self):
        chunk = 256
        plens = [1000, 513, 257, 2048]
        eng = ServeEngine(LLAMA8B, batch_policy=ChunkedPrefillPolicy(
            chunk_tokens=chunk, max_batch=8))
        rep = eng.run(_fixed_reqs(plens))
        assert rep.prefill_chunks == sum(math.ceil(p / chunk)
                                         for p in plens)
        assert rep.n == len(plens)
        for r in rep.requests:
            assert r.prefilled_tokens == r.prompt_len
        # chunks are exact, so chunked phases add no padding
        assert rep.prefill_padding_fraction == 0.0

    def test_short_prompts_match_slot_count(self):
        reqs = _reqs(16, prompt_range=(100, 1000))
        a = ServeEngine(LLAMA8B, batch_policy=ChunkedPrefillPolicy(
            chunk_tokens=8192, max_batch=8)).run(_reqs(
                16, prompt_range=(100, 1000)))
        c = ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy(
            max_batch=8)).run(reqs)
        assert _report_sig(a) == _report_sig(c)

    def test_long_prompt_does_not_stall_decode(self):
        # short requests admitted first keep decoding while the long
        # prompt chunks: their latency must beat the monolithic path
        reqs = _fixed_reqs([300, 300, 300, 300, 6000], out=200)
        chunked = ServeEngine(LLAMA8B, batch_policy=ChunkedPrefillPolicy(
            chunk_tokens=512, max_batch=8)).run(reqs)
        mono = ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy(
            max_batch=8, bucket_prefill=False)).run(
                _fixed_reqs([300, 300, 300, 300, 6000], out=200))
        by_id = {r.req_id: r for r in chunked.requests}
        mono_by = {r.req_id: r for r in mono.requests}
        short_chunked = max(by_id[i].latency for i in range(4))
        short_mono = max(mono_by[i].latency for i in range(4))
        assert short_chunked <= short_mono


# ---------------------------------------------------------------------------
# macro-stepping parity for every policy
# ---------------------------------------------------------------------------
class TestMacroParity:
    @pytest.mark.parametrize("name, params", [
        ("slot_count", {}),
        ("token_budget", {"token_budget": 8192}),
        ("length_sorted", {}),
        ("chunked_prefill", {"chunk_tokens": 512}),
    ])
    def test_macro_equals_single_step(self, name, params):
        def run(macro):
            pol = make_batch_policy(name, max_batch=8, **params)
            eng = ServeEngine(LLAMA8B, batch_policy=pol,
                              macro_step=macro)
            return eng.run(_reqs(16, gap=0.15))
        assert _report_sig(run(True)) == _report_sig(run(False))


# ---------------------------------------------------------------------------
# disaggregated prefill/decode serving
# ---------------------------------------------------------------------------
class TestDisaggregated:
    def test_cluster_hands_off_every_request(self):
        spec = ExperimentSpec(n_requests=16, replicas=3, disaggregate=1,
                              arrival="poisson",
                              arrival_params={"rate_per_s": 4.0})
        res = spec.run()
        assert res.kind == "cluster"
        assert res.n_requests == 16 and res.n_shed == 0
        assert res.n_handoffs == 16
        assert res.handoff_energy_j > 0.0
        # handoff energy is part of the fleet total
        rep = res.report
        assert rep.total_energy_j == pytest.approx(
            sum(r.total_energy_j for r in rep.replica_reports)
            + rep.handoff_energy_j)
        # decode replicas own the finished requests; prefill pool none
        assert sum(rep.requests_per_replica) == 16
        assert rep.requests_per_replica[0] == 0

    def test_handoff_energy_scales_with_kv(self):
        def run(prompt):
            return ExperimentSpec(
                n_requests=8, replicas=2, disaggregate=1,
                prompt_range=(prompt, prompt),
                output_range=(20, 20)).run().handoff_energy_j
        assert run(2000) > run(400)

    def test_pool_validation(self):
        mixed = ServeEngine(LLAMA8B)
        pooled = ServeEngine(LLAMA8B, pool="prefill")
        with pytest.raises(ValueError, match="unknown pool"):
            ServeEngine(LLAMA8B, pool="bogus")
        from repro.serving.cluster import ClusterEngine
        with pytest.raises(ValueError, match="mix"):
            ClusterEngine([mixed, pooled])
        with pytest.raises(ValueError):
            ClusterEngine([ServeEngine(LLAMA8B, pool="prefill"),
                           ServeEngine(LLAMA8B, pool="prefill")])

    def test_make_cluster_rejects_shared_policy(self):
        with pytest.raises(ValueError, match="shared across replicas"):
            make_cluster(LLAMA8B, 2,
                         batch_policy=SlotCountPolicy(max_batch=8))
        make_cluster(LLAMA8B, 1, batch_policy=SlotCountPolicy(
            max_batch=8))                # single replica is fine
