"""InferenceBackend protocol tests: analytic parity with the
pre-refactor engine (bit-identical golden reports), executed-backend
equivalence with the legacy ``execute=True`` path, replay round trips,
DVFS device scaling, and the ServeReport empty-run guards."""
import json
import os

import numpy as np
import pytest

from repro.api import ExperimentSpec
from repro.configs import get_config
from repro.configs.paper_zoo import PAPER_MODELS
from repro.core.hardware import H100_SXM, TPU_V5E
from repro.core.profiler import PhaseProfiler
from repro.serving.backend import (AnalyticBackend, DecodeBatch,
                                   ExecutedBackend, PhaseResult,
                                   PrefillBatch, RecordingBackend,
                                   ReplayBackend, REPLAY_SCHEMA,
                                   make_backend)
from repro.serving.engine import ServeEngine, ServeReport
from repro.serving.requests import Request
from repro.batching.policy import SlotCountPolicy

LLAMA8B = PAPER_MODELS["llama-3.1-8b"]
DATA = os.path.join(os.path.dirname(__file__), "data")
FIXTURE = os.path.join(DATA, "replay_h100_small.json")


def _reqs(n, *, plen=256, out=8, gap=0.05):
    return [Request(req_id=i, prompt=None, prompt_len=plen,
                    max_new_tokens=out, arrival_time=gap * i)
            for i in range(n)]


# ---------------------------------------------------------------------------
# analytic parity: the refactor must not move a single bit
# ---------------------------------------------------------------------------
class TestGoldenParity:
    """Every RunResult captured from the pre-backend engine must
    reproduce byte-identically (spec hash included)."""

    with open(os.path.join(DATA, "golden_pre_refactor.json")) as f:
        GOLDEN = json.load(f)["records"]

    @pytest.mark.parametrize("name", sorted(GOLDEN))
    def test_reproduces_pre_refactor_record(self, name):
        rec = self.GOLDEN[name]
        spec = ExperimentSpec.from_dict(rec["spec"])
        assert spec.spec_hash() == rec["spec_hash"], \
            "spec serialization drifted from the pre-refactor hash"
        assert spec.run().to_json() == rec["result"]

    def test_explicit_analytic_backend_is_default(self):
        a = ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(20))
        b = ServeEngine(LLAMA8B,
                        backend=AnalyticBackend(LLAMA8B), batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(20))
        assert a.total_energy_j == b.total_energy_j
        assert a.wall_time_s == b.wall_time_s
        assert a.busy_energy_j == b.busy_energy_j
        assert [r.t_done for r in a.requests] == \
            [r.t_done for r in b.requests]

    def test_profiler_backend_parity(self):
        default = PhaseProfiler(LLAMA8B)
        explicit = PhaseProfiler(LLAMA8B,
                                 backend=AnalyticBackend(
                                     LLAMA8B, n_chips=1))
        assert (default.profile_prefill(4, 1200).energy_j
                == explicit.profile_prefill(4, 1200).energy_j)
        assert (default.profile_decode(4, 1200, 80).latency
                == explicit.profile_decode(4, 1200, 80).latency)


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------
class TestProtocol:
    def _conform(self, backend):
        backend.start()
        r = _reqs(1)[0]
        pre = backend.prefill(PrefillBatch(picks=[(None, r)],
                                           pad_len=r.prompt_len,
                                           stack="eager"))
        dec = backend.decode_step(DecodeBatch(
            slots=[0], requests=[r], cache_lens=[r.prompt_len + 1]))
        tail = backend.decode_tail(r, 4)
        idle = backend.idle(1.0, "idle")
        gated = backend.idle(1.0, "gated")
        for res in (pre, dec, tail, idle, gated):
            assert isinstance(res, PhaseResult)
            assert np.isfinite(res.latency_s) and res.latency_s >= 0
            assert np.isfinite(res.energy_j) and res.energy_j >= 0
        assert pre.phase == "prefill" and dec.phase == "decode"
        assert idle.phase == "idle" and gated.phase == "gated"
        assert gated.energy_j <= idle.energy_j
        backend.release_slot(0)

    def test_analytic_conforms(self):
        self._conform(AnalyticBackend(LLAMA8B))

    def test_replay_conforms(self):
        self._conform(ReplayBackend.from_json(FIXTURE))

    def test_make_backend(self):
        assert isinstance(make_backend("analytic", LLAMA8B),
                          AnalyticBackend)
        assert isinstance(
            make_backend("replay", LLAMA8B, replay_path=FIXTURE),
            ReplayBackend)
        with pytest.raises(ValueError, match="unknown backend"):
            make_backend("nvml", LLAMA8B)


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------
class TestReplay:
    def test_roundtrip_matches_analytic(self):
        """Record an analytic run, replay it through the same
        scheduler: the report reproduces within aggregation noise."""
        rec = RecordingBackend(AnalyticBackend(LLAMA8B))
        ref = ServeEngine(LLAMA8B, backend=rec, batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(24))
        replay = ReplayBackend(rec.to_trace(model=LLAMA8B.name))
        rep = ServeEngine(LLAMA8B,
                          backend=replay, batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(24))
        assert rep.total_energy_j == pytest.approx(
            ref.total_energy_j, rel=0.02)
        assert rep.wall_time_s == pytest.approx(ref.wall_time_s, rel=0.02)
        assert rep.n_decode_steps == ref.n_decode_steps

    def test_deterministic(self):
        backend = ReplayBackend.from_json(FIXTURE)
        a = ServeEngine(LLAMA8B, backend=backend, batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(16))
        b = ServeEngine(LLAMA8B, backend=backend, batch_policy=SlotCountPolicy(max_batch=8)).run(_reqs(16))
        assert a.total_energy_j == b.total_energy_j
        assert a.wall_time_s == b.wall_time_s

    def test_fixture_via_spec_axis(self):
        spec = ExperimentSpec(model="llama-3.1-8b", backend="replay",
                              replay_path=FIXTURE, n_requests=12,
                              max_batch=8)
        res = spec.run()
        assert res.n_requests == 12
        assert res.total_energy_j > 0
        # the replay axis is part of the spec's identity
        assert spec.spec_hash() != spec.derive(backend="analytic",
                                               replay_path=None).spec_hash()

    def test_schema_validation(self):
        with pytest.raises(ValueError, match="schema"):
            ReplayBackend({"schema": "bogus/v9", "prefill": [],
                           "decode": []})
        good = json.load(open(FIXTURE))
        with pytest.raises(ValueError, match="no 'prefill' samples"):
            ReplayBackend({**good, "prefill": []})
        bad = {**good, "decode": [{"batch": 1, "latency_s": 0.1}]}
        with pytest.raises(ValueError, match="missing"):
            ReplayBackend(bad)
        no_idle = {k: v for k, v in good.items() if k != "idle_power_w"}
        with pytest.raises(ValueError, match="idle_power_w"):
            ReplayBackend(no_idle)

    def test_recording_without_idle_gaps_exports_device_idle(self):
        """A saturated recording (no gaps) must not export 0 W idle —
        it falls back to the inner backend's device states."""
        rec = RecordingBackend(AnalyticBackend(LLAMA8B))
        reqs = [Request(req_id=i, prompt=None, prompt_len=64,
                        max_new_tokens=4, arrival_time=0.0)
                for i in range(4)]
        ServeEngine(LLAMA8B, backend=rec, batch_policy=SlotCountPolicy(max_batch=4)).run(reqs)
        trace = rec.to_trace()
        assert trace["idle_power_w"] == H100_SXM.idle_power
        assert trace["gated_power_w"] == H100_SXM.gated_power

    def test_replay_specs_never_memoized(self, tmp_path):
        """Re-recording a trace file must re-run the spec — the spec
        hash cannot see trace content, so run_spec refuses to cache."""
        from repro.sweep import run_spec
        rec = RecordingBackend(AnalyticBackend(LLAMA8B))
        ServeEngine(LLAMA8B, backend=rec, batch_policy=SlotCountPolicy(max_batch=4)).run(_reqs(8))
        path = str(tmp_path / "trace.json")
        trace = rec.dump(path)
        spec = ExperimentSpec(model="llama-3.1-8b", backend="replay",
                              replay_path=path, n_requests=8,
                              max_batch=4)
        first, hit1 = run_spec(spec, cache_dir=str(tmp_path / "cc"))
        # re-record with doubled power: same path, new content
        for s in trace["prefill"] + trace["decode"]:
            s["power_w"] *= 2.0
        with open(path, "w") as f:
            json.dump(trace, f)
        second, hit2 = run_spec(spec, cache_dir=str(tmp_path / "cc"))
        assert not hit1 and not hit2
        assert second.busy_energy_j == pytest.approx(
            2 * first.busy_energy_j, rel=1e-6)

    def test_recording_forwards_cost_identity(self):
        scaled = H100_SXM.with_freq_scale(0.5)
        inner = AnalyticBackend(LLAMA8B, device=scaled)
        rec = RecordingBackend(inner)
        eng = ServeEngine(LLAMA8B, backend=rec, batch_policy=SlotCountPolicy(max_batch=4))
        # routers/schedulers must price with the inner backend's device
        assert eng.device is scaled
        assert eng.energy is inner.energy

    def test_recording_emits_valid_schema(self, tmp_path):
        rec = RecordingBackend(AnalyticBackend(LLAMA8B))
        ServeEngine(LLAMA8B, backend=rec, batch_policy=SlotCountPolicy(max_batch=4)).run(_reqs(8))
        trace = rec.dump(str(tmp_path / "t.json"), device="h100-sxm")
        assert trace["schema"] == REPLAY_SCHEMA
        assert trace["prefill"] and trace["decode"]
        assert trace["idle_power_w"] == H100_SXM.idle_power
        ReplayBackend.from_json(str(tmp_path / "t.json"))  # must load


# ---------------------------------------------------------------------------
# executed backend == legacy execute=True
# ---------------------------------------------------------------------------
class TestExecuted:
    def _setup(self):
        import jax
        from repro.models import build_model
        cfg = get_config("stablelm-1.6b").reduced()
        m = build_model(cfg, fmt="float32")
        return cfg, m, m.init(jax.random.PRNGKey(0))

    def _prompts(self, cfg, n=4, seed=0):
        rng = np.random.default_rng(seed)
        return [Request(req_id=i,
                        prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32),
                        prompt_len=8, max_new_tokens=4, arrival_time=0.0)
                for i in range(n)]

    def test_backend_axis_spelling_runs_end_to_end(self):
        """backend="executed" must behave like execute=True, including
        prompt materialization in spec.requests()."""
        spec = ExperimentSpec(model="stablelm-1.6b", backend="executed",
                              reduced=True, fmt="float32", n_requests=3,
                              max_batch=4, buf_len=32,
                              prompt_range=(4, 8), output_range=(2, 4))
        assert all(r.prompt is not None for r in spec.requests())
        res = spec.run()
        assert all(len(r.generated) == r.max_new_tokens
                   for r in res.report.requests)

    def test_execute_conflicts_with_foreign_backend(self):
        with pytest.raises(ValueError, match="conflicts"):
            ServeEngine(LLAMA8B, execute=True,
                        backend=AnalyticBackend(LLAMA8B))

    def test_cache_slot_insert_evict_helpers(self):
        import jax.numpy as jnp
        from repro.batching.continuous import (evict_cache_slot,
                                               insert_cache_slot)
        cache = {"k": jnp.zeros((2, 4, 8)), "pos": jnp.zeros((4,))}
        pcache = {"k": jnp.ones((2, 3, 8)), "pos": 5 * jnp.ones((3,))}
        cache = insert_cache_slot(cache, pcache, row=1, slot=2)
        assert float(cache["k"][0, 2, 0]) == 1.0
        assert float(cache["pos"][2]) == 5.0
        assert float(cache["k"][0, 0, 0]) == 0.0    # other slots intact
        cache = evict_cache_slot(cache, slot=2)
        assert float(cache["k"][0, 2, 0]) == 0.0
        assert float(cache["pos"][2]) == 0.0

    def test_backend_kwarg_matches_legacy_execute(self):
        cfg, m, params = self._setup()
        legacy = ServeEngine(cfg, fmt="float32", mode="continuous",
                             execute=True, model=m, params=params,
                             buf_len=32, batch_policy=SlotCountPolicy(max_batch=4, max_prefill_batch=2))
        rep_a = legacy.run(self._prompts(cfg))
        assert isinstance(legacy.backend, ExecutedBackend)
        explicit = ServeEngine(
            cfg, fmt="float32", mode="continuous",
            backend=ExecutedBackend(cfg, m, params, max_batch=4,
                                    buf_len=32, fmt="float32"), batch_policy=SlotCountPolicy(max_batch=4, max_prefill_batch=2))
        rep_b = explicit.run(self._prompts(cfg))
        assert explicit.execute
        # identical analytic clocks AND identical real generations
        assert rep_a.total_energy_j == rep_b.total_energy_j
        assert rep_a.wall_time_s == rep_b.wall_time_s
        assert ([r.generated for r in rep_a.requests]
                == [r.generated for r in rep_b.requests])
        assert all(len(r.generated) == r.max_new_tokens
                   for r in rep_b.requests)


# ---------------------------------------------------------------------------
# DVFS device states
# ---------------------------------------------------------------------------
class TestDVFS:
    def test_scaling_laws(self):
        d = H100_SXM.with_freq_scale(0.7)
        assert d.freq_scale == 0.7
        assert d.peak_flops_16 == pytest.approx(
            H100_SXM.peak_flops_16 * 0.7)
        # dynamic power scales ~f^3 above the static (idle) floor
        expect = (H100_SXM.idle_power
                  + (H100_SXM.power_memory - H100_SXM.idle_power)
                  * 0.7 ** 3)
        assert d.power_memory == pytest.approx(expect)
        # HBM domain, host overhead and non-serving states unchanged
        assert d.hbm_bw == H100_SXM.hbm_bw
        assert d.idle_power == H100_SXM.idle_power
        assert d.gated_power == H100_SXM.gated_power
        assert d.launch_overhead_fused == H100_SXM.launch_overhead_fused

    def test_identity_and_errors(self):
        assert H100_SXM.with_freq_scale(1.0) is H100_SXM
        scaled = H100_SXM.with_freq_scale(0.5)
        assert scaled.with_freq_scale(1.0) is scaled
        with pytest.raises(ValueError, match="positive"):
            H100_SXM.with_freq_scale(0.0)
        with pytest.raises(ValueError, match="outside"):
            TPU_V5E.with_freq_scale(0.01)
        with pytest.raises(ValueError, match="outside"):
            # the *combined* scale is bounds-checked, not the step
            H100_SXM.with_freq_scale(0.5).with_freq_scale(0.15)

    def test_composition_is_multiplicative_and_exact(self):
        """Repeated application composes: scaling by a then b lands on
        the same operating point as scaling once by a*b — so a DVFS
        controller re-targeting a live device never accumulates
        drift."""
        once = H100_SXM.with_freq_scale(0.4)
        twice = H100_SXM.with_freq_scale(0.8).with_freq_scale(0.5)
        assert twice.freq_scale == pytest.approx(0.4)
        assert twice.name == once.name == "h100-sxm@f0.4"
        for f in ("peak_flops_16", "power_memory", "power_mxu",
                  "power_scalar", "hbm_bw", "idle_power", "gated_power"):
            assert getattr(twice, f) == pytest.approx(
                getattr(once, f), rel=1e-12), f
        # and it round-trips back up: 0.4 -> 1.0 via a 2.5x step
        back = twice.with_freq_scale(2.5)
        assert back.freq_scale == pytest.approx(1.0)
        assert back.power_memory == pytest.approx(
            H100_SXM.power_memory, rel=1e-12)

    def test_power_states_table(self):
        states = H100_SXM.power_states()
        assert states["idle"].power_w == H100_SXM.idle_power
        assert states["gated"].wake_latency_s == H100_SXM.wake_latency_s
        assert states["active"].serves and not states["idle"].serves
        with pytest.raises(ValueError, match="no nominal power"):
            H100_SXM.state_power("active")

    def test_downclock_wins_memory_bound_decode(self):
        """The paper-level claim: in the memory-bound decode regime a
        sub-nominal frequency point beats nominal on Wh/request."""
        base = ExperimentSpec(model="llama-3.1-8b", max_batch=32,
                              n_requests=32, prompt_range=(200, 600),
                              output_range=(150, 300))
        nominal = base.run().mean_energy_wh
        slow = base.derive(freq_scale=0.6).run().mean_energy_wh
        assert slow < nominal

    def test_freq_scale_threads_to_all_layers(self):
        spec = ExperimentSpec(model="llama-3.1-8b", freq_scale=0.8)
        assert spec.device_spec().freq_scale == 0.8
        eng = spec.build_engine()
        assert eng.device.freq_scale == 0.8
        assert eng.backend.device.freq_scale == 0.8
        assert eng.energy.device.freq_scale == 0.8


# ---------------------------------------------------------------------------
# spec-hash stability + serialization of the new axes
# ---------------------------------------------------------------------------
class TestSpecAxes:
    def test_defaults_keep_old_hashes(self):
        """Default-valued new fields must not appear in the canonical
        JSON, so every pre-existing spec hash survives the release."""
        d = ExperimentSpec(model="llama-3.1-8b").to_dict()
        assert "backend" not in d
        assert "freq_scale" not in d
        assert "replay_path" not in d

    @pytest.mark.parametrize("changes", [
        {"freq_scale": 0.75},
        {"backend": "replay", "replay_path": FIXTURE},
    ])
    def test_off_default_round_trips(self, changes):
        spec = ExperimentSpec(model="llama-3.1-8b", **changes)
        clone = ExperimentSpec.from_json(spec.to_json())
        assert clone == spec
        assert clone.spec_hash() == spec.spec_hash()
        assert (spec.spec_hash()
                != ExperimentSpec(model="llama-3.1-8b").spec_hash())

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown backend"):
            ExperimentSpec(backend="nvml")
        with pytest.raises(ValueError, match="freq_scale"):
            ExperimentSpec(freq_scale=0.01)
        with pytest.raises(ValueError, match="replay_path"):
            ExperimentSpec(backend="replay")
        with pytest.raises(ValueError, match="did you mean"):
            ExperimentSpec(replay_path=FIXTURE)
        with pytest.raises(ValueError, match="conflict"):
            ExperimentSpec(backend="replay", replay_path=FIXTURE,
                           execute=True)
        with pytest.raises(ValueError, match="profile"):
            ExperimentSpec(pipeline="profile", backend="replay",
                           replay_path=FIXTURE)
        with pytest.raises(ValueError, match="analytic backends only"):
            ExperimentSpec(pipeline="profile", backend="executed")
        with pytest.raises(ValueError, match="analytic backends only"):
            ExperimentSpec(pipeline="profile", execute=True)
        with pytest.raises(ValueError, match="no effect on replayed"):
            ExperimentSpec(backend="replay", replay_path=FIXTURE,
                           freq_scale=0.5)

    def test_engine_kwargs_cannot_contradict_backend(self):
        with pytest.raises(ValueError, match="conflicts with the "
                                             "backend's device"):
            ServeEngine(LLAMA8B, device=TPU_V5E,
                        backend=AnalyticBackend(LLAMA8B))
        with pytest.raises(ValueError, match="precision policy"):
            ServeEngine(LLAMA8B, fmt="int8",
                        backend=AnalyticBackend(LLAMA8B))
        # matching kwargs (or defaults) stay accepted
        ServeEngine(LLAMA8B, fmt="int8",
                    backend=AnalyticBackend(LLAMA8B, fmt="int8"))
        ServeEngine(LLAMA8B, backend=AnalyticBackend(LLAMA8B))
        # equal-but-distinct DeviceSpec objects are NOT a conflict
        ServeEngine(LLAMA8B, device=H100_SXM.with_freq_scale(0.8),
                    backend=AnalyticBackend(
                        LLAMA8B, device=H100_SXM.with_freq_scale(0.8)))


# ---------------------------------------------------------------------------
# ServeReport guards (satellite: tokens_per_s over completed only)
# ---------------------------------------------------------------------------
class TestReportGuards:
    def test_empty_run_all_aggregates_finite(self):
        rep = ServeEngine(LLAMA8B, batch_policy=SlotCountPolicy(max_batch=4)).run([])
        assert rep.tokens_per_s == 0.0
        assert rep.mean_energy_per_request_wh == 0.0
        for v in rep.summary().values():
            assert np.isfinite(v)

    def test_tokens_per_s_counts_completed_only(self):
        done = _reqs(2, out=4)
        for r in done:
            r.tokens_generated = 4
            r.t_done = 1.0
        stuck = _reqs(1, out=4)[0]
        stuck.tokens_generated = 2        # never finished
        rep = ServeReport(requests=done + [stuck], total_energy_j=1.0,
                          busy_energy_j=1.0, idle_energy_j=0.0,
                          wall_time_s=2.0, busy_time_s=2.0,
                          mean_batch=1.0)
        assert rep.tokens_per_s == pytest.approx(8 / 2.0)
