"""End-to-end system tests: training convergence, quantized-model
serving, checkpoint round-trips through the serving engine, and the
paper's headline result reproduced through the full stack."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.batching.policy import SlotCountPolicy
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving import ServeEngine, Request, fixed_arrivals
from repro.training import train, AdamWConfig
from repro.training.checkpoint import save_checkpoint, load_checkpoint
from repro.training.data import SyntheticLM, DataConfig

LLAMA8B = ModelConfig(name="llama-3.1-8b", family="dense", num_layers=32,
                      d_model=4096, num_heads=32, num_kv_heads=8,
                      d_ff=14336, vocab_size=128256)


def test_training_reduces_loss():
    cfg = get_config("h2o-danube-3-4b").reduced()
    m = build_model(cfg, fmt="float32")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  batch_size=4))
    losses = []
    train(m, data.batches(), n_steps=25, log_every=0,
          opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5),
          callback=lambda s, met: losses.append(float(met["lm_loss"])))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8


def test_checkpoint_then_serve(tmp_path):
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg, fmt="float32")
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params, step=3)
    params2, _, step = load_checkpoint(path)
    assert step == 3
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    reqs = [Request(req_id=0, prompt=prompt, prompt_len=8,
                    max_new_tokens=4, arrival_time=0.0)]
    eng = ServeEngine(cfg, mode="continuous", execute=True,
                      model=m, params=params2, buf_len=32, batch_policy=SlotCountPolicy(max_batch=2))
    rep = eng.run(reqs)
    assert len(rep.requests[0].generated) == 4


def test_quantized_model_generates_same_scale_logits():
    """PTQ int8 model produces logits close to fp32 (end-to-end)."""
    cfg = get_config("minitron-8b").reduced()
    m32 = build_model(cfg, fmt="float32")
    params = m32.init(jax.random.PRNGKey(0))
    m8 = build_model(cfg, fmt="int8")
    q = m8.quantize(jax.tree.map(lambda x: x, params))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    h32, _ = m32.forward_train(params, {"tokens": toks})
    h8, _ = m8.forward_train(q, {"tokens": toks})
    l32 = m32.logits(params, h32[:, -1])
    l8 = m8.logits(q, h8[:, -1])
    # same argmax on a clear majority of rows, bounded drift
    rel = float(jnp.linalg.norm(l8 - l32) / jnp.linalg.norm(l32))
    assert rel < 0.25


def test_paper_headline_through_full_stack():
    """Naive fp32 sequential vs shaped continuous bf16 >= 10x."""
    def reqs():
        return [Request(req_id=i, prompt=None, prompt_len=256,
                        max_new_tokens=32, arrival_time=t)
                for i, t in enumerate(fixed_arrivals(80, 0.01))]
    naive = ServeEngine(LLAMA8B, fmt="float32", mode="sequential").run(
        [Request(req_id=i, prompt=None, prompt_len=256,
                 max_new_tokens=32, arrival_time=0.0)
         for i in range(80)])
    opt = ServeEngine(LLAMA8B, fmt="bfloat16", mode="continuous", batch_policy=SlotCountPolicy(max_batch=64)).run(reqs())
    ratio = (naive.mean_energy_per_request_wh
             / opt.mean_energy_per_request_wh)
    assert ratio >= 10


def test_dryrun_small_mesh_subprocess():
    """The dry-run path lowers on a small host-device mesh (the 512-
    device production sweep runs via launch/dryrun.py; this pins the
    machinery in CI-sized form)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_platforms", "cpu")
import jax.numpy as jnp
from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch.dryrun import build_step
from repro.configs.base import ShapeConfig
from repro.models import build_model

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("minitron-8b").reduced()
model = build_model(cfg, fmt="bfloat16")
shape = ShapeConfig("tiny_train", 64, 4, "train")
fn, args, ins, outs = build_step(model, shape, mesh)
with mesh:
    j = jax.jit(fn, in_shardings=sh.named(mesh, ins),
                out_shardings=sh.named(mesh, outs))
    c = j.lower(*args).compile()
    ca = c.cost_analysis()
print("SUBPROCESS_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    # hermetic CPU child — see test_perf_features for the rationale
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("TPU_LIBRARY_PATH", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
