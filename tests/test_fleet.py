"""Fleet subsystem tests: seeded vectorized-vs-legacy parity
(field-for-field report and per-replica power-trace equality),
autoscaler lifecycle + energy conservation (transition energy billed,
ledger closes to 100%), region signal exactness, geo accounting,
diurnal arrival statistics, and the ExperimentSpec fleet axes
(default-omitting serialization, validation)."""
import json
import math

import numpy as np
import pytest

from repro.api import ExperimentSpec
from repro.configs.paper_zoo import PAPER_MODELS
from repro.fleet import (FleetEngine, FleetView, QueueDepthAutoscaler,
                         Signal, TargetUtilizationAutoscaler,
                         assign_replicas, load_regions, make_autoscaler,
                         make_fleet, sinusoid_region)
from repro.serving import make_cluster, make_router, poisson_arrivals
from repro.serving.arrival import (burst_arrivals, diurnal_arrivals,
                                   paper_requests)
from repro.serving.trace import PowerTrace

LLAMA8B = PAPER_MODELS["llama-3.1-8b"]


def _reqs(n, seed=0, rate=10.0, arrivals=None):
    arr = arrivals if arrivals is not None \
        else poisson_arrivals(n, rate, seed=seed)
    return paper_requests(n, arr, seed=seed)


def _fields(rep):
    """Every scalar + per-request + per-replica field that parity
    guarantees bit-identical between the legacy loop and the fleet."""
    return {
        "total": rep.total_energy_j, "busy": rep.busy_energy_j,
        "idle": rep.idle_energy_j, "gated": rep.gated_energy_j,
        "wall": rep.wall_time_s, "n": rep.n, "shed": rep.n_shed,
        "per_replica_n": rep.requests_per_replica,
        "replica_scalars": [(r.total_energy_j, r.busy_energy_j,
                             r.idle_energy_j, r.gated_energy_j,
                             r.wall_time_s, r.busy_time_s, r.mean_batch)
                            for r in rep.replica_reports],
        "requests": sorted((r.req_id, r.t_prefill_start, r.t_first_token,
                            r.t_done, r.energy_j, r.tokens_generated)
                           for rep_ in rep.replica_reports
                           for r in rep_.requests),
    }


def _segs(trace):
    # the two engines append segments in different global orders (the
    # legacy loop interleaves replicas; the fleet advances one replica
    # at a time), but each replica's own timeline must be identical
    return sorted((s.replica, s.t0, s.t1, s.state, s.energy_j, s.batch)
                  for s in trace.segments)


class TestFleetParity:
    """The acceptance bar: on small fleets the vectorized path is
    field-for-field identical to ClusterEngine, per-trace-segment
    included, across router policies and fleet sizes."""

    @pytest.mark.parametrize("policy", ["round_robin", "least_loaded",
                                        "shortest_work", "energy_aware",
                                        "round_robin_gated"])
    @pytest.mark.parametrize("n_rep", [1, 3])
    def test_report_and_trace_parity(self, policy, n_rep):
        tr_a, tr_b = PowerTrace(), PowerTrace()
        cl = make_cluster(LLAMA8B, n_rep, policy=policy, max_batch=8)
        fl = make_fleet(LLAMA8B, n_rep, policy=policy, max_batch=8)
        ra = cl.run(_reqs(60, seed=11, rate=12.0), trace=tr_a)
        rb = fl.run(_reqs(60, seed=11, rate=12.0), trace=tr_b)
        assert _fields(ra) == _fields(rb)
        assert _segs(tr_a) == _segs(tr_b)

    def test_parity_on_bursts(self):
        arr = burst_arrivals(48, 12, 2.0)
        cl = make_cluster(LLAMA8B, 4, policy="least_loaded", max_batch=6)
        fl = make_fleet(LLAMA8B, 4, policy="least_loaded", max_batch=6)
        ra = cl.run(_reqs(48, seed=3, arrivals=arr))
        rb = fl.run(_reqs(48, seed=3, arrivals=arr))
        assert _fields(ra) == _fields(rb)

    def test_api_vector_result_identical(self):
        base = dict(model="llama-3.1-8b", pipeline="serve",
                    mode="continuous", n_requests=80, replicas=3,
                    router="least_loaded", arrival="poisson",
                    arrival_params={"rate_per_s": 10.0}, seed=5)
        d1 = ExperimentSpec(**base).run().to_dict()
        d2 = ExperimentSpec(fleet="vector", **base).run().to_dict()
        d1.pop("spec_hash"), d2.pop("spec_hash")
        assert d1 == d2

    def test_rejects_sequential_replicas(self):
        eng = make_cluster(LLAMA8B, 1, policy="round_robin").replicas[0]
        eng.mode = "sequential"
        with pytest.raises(ValueError, match="continuous"):
            FleetEngine([eng])


class TestAutoscaler:
    def _autoscaled(self, trace=None):
        auto = TargetUtilizationAutoscaler(check_interval_s=5.0,
                                           min_replicas=1)
        fl = make_fleet(LLAMA8B, 6, policy="least_loaded", max_batch=4,
                        autoscaler=auto)
        reqs = _reqs(160, seed=7, arrivals=diurnal_arrivals(
            160, 30.0, period_s=120.0, amp_frac=0.9, seed=7))
        return fl.run(reqs, trace=trace)

    def test_scales_and_conserves_energy(self):
        tr = PowerTrace()
        rep = self._autoscaled(trace=tr)
        assert rep.n_transitions > 0
        assert rep.transition_energy_j > 0
        # the ledger closes: trace total == report total, and the
        # report total already includes transition energy
        assert tr.total_energy_j == pytest.approx(rep.total_energy_j,
                                                  rel=1e-9)
        by_state = tr.energy_by_state()
        trans = by_state.get("spinup", 0.0) + by_state.get("drain", 0.0)
        assert trans == pytest.approx(rep.transition_energy_j, rel=1e-9)
        parts = (rep.busy_energy_j + rep.idle_energy_j
                 + rep.gated_energy_j + rep.transition_energy_j)
        assert parts == pytest.approx(rep.total_energy_j, rel=1e-9)

    def test_all_requests_complete(self):
        rep = self._autoscaled()
        assert rep.n == 160
        assert all(r.t_done >= r.arrival_time for r in rep.requests)

    def test_zero_request_replicas_no_nan(self):
        """Satellite: drained / never-scaled-up replicas must not put
        NaN in any per-replica report row."""
        auto = TargetUtilizationAutoscaler(check_interval_s=5.0)
        fl = make_fleet(LLAMA8B, 6, policy="least_loaded",
                        autoscaler=auto)
        rep = fl.run(_reqs(30, seed=1, rate=4.0))
        assert 0 in rep.requests_per_replica   # some replica never ran
        rows = rep.per_replica_summary()
        for row in rows:
            for v in row.values():
                assert not (isinstance(v, float) and math.isnan(v))
        for d in (rep.latency_percentiles_per_replica()
                  + rep.ttft_percentiles_per_replica()):
            assert all(not math.isnan(v) for v in d.values())
        assert all(not (isinstance(v, float) and math.isnan(v))
                   for v in rep.summary().values())

    def test_policy_desired(self):
        t = TargetUtilizationAutoscaler(target=0.5, band=0.1)
        # inside the band: hold
        v = FleetView(t=0, n_active=2, n_total=4, queued=8, busy=2,
                      max_batch=8)
        assert t.desired(v) == 2
        # way above: grow toward target utilization
        v = FleetView(t=0, n_active=1, n_total=4, queued=32, busy=1,
                      max_batch=8)
        assert t.desired(v) == 8
        q = QueueDepthAutoscaler(high=8.0, low=1.0)
        v = FleetView(t=0, n_active=2, n_total=8, queued=32, busy=2,
                      max_batch=8)
        assert q.desired(v) > 2
        v = FleetView(t=0, n_active=4, n_total=8, queued=1, busy=1,
                      max_batch=8)
        assert q.desired(v) < 4

    def test_clamp_and_factory(self):
        a = make_autoscaler("queue_depth", {"min_replicas": 2,
                                            "max_replicas": 5})
        assert a.clamp(0, 100) == 2
        assert a.clamp(50, 100) == 5
        assert a.clamp(50, 3) == 3
        with pytest.raises(ValueError, match="unknown autoscaler"):
            make_autoscaler("nope", {})


class TestSignal:
    def test_integral_is_exact(self):
        sig = Signal([0.0, 2.0, 5.0], [1.0, 3.0, 0.0])
        # trapezoid areas: [0,2]: 4.0, [2,5]: 4.5
        assert sig.integral(0, 5) == pytest.approx(8.5)
        assert sig.integral(1, 3) == pytest.approx(
            np.trapezoid([sig.at(t) for t in np.linspace(1, 3, 20001)],
                         np.linspace(1, 3, 20001)), rel=1e-7)

    def test_periodic_wrap(self):
        sig = Signal([0.0, 6.0, 18.0], [1.0, 5.0, 2.0], period_s=24.0)
        for t in (0.0, 3.7, 11.2, 23.9):
            assert sig.at(t + 24.0) == pytest.approx(sig.at(t))
        one_period = sig.integral(0.0, 24.0)
        assert sig.integral(24.0, 72.0) == pytest.approx(2 * one_period)
        # windows spanning the wrap are still exact
        assert sig.integral(20.0, 28.0) == pytest.approx(
            sig.integral(20.0, 24.0) + sig.integral(0.0, 4.0))

    def test_mean_zero_width_is_point_value(self):
        sig = Signal([0.0, 10.0], [2.0, 4.0])
        assert sig.mean(5.0, 5.0) == pytest.approx(sig.at(5.0))

    def test_validation(self):
        with pytest.raises(ValueError, match="strictly increasing"):
            Signal([0.0, 0.0], [1.0, 2.0])
        with pytest.raises(ValueError, match="period_s"):
            Signal([0.0, 30.0], [1.0, 2.0], period_s=24.0)


class TestRegions:
    def test_load_and_assign(self):
        regs = load_regions([sinusoid_region("us", replicas=2),
                             sinusoid_region("eu", replicas=1)])
        assert [r.name for r in regs] == ["us", "eu"]
        assert assign_replicas(regs, 3) == [0, 0, 1]
        even = load_regions([{"name": "a"}, {"name": "b"}])
        assert assign_replicas(even, 5) == [0, 0, 0, 1, 1]

    def test_validation(self):
        with pytest.raises(ValueError, match="duplicate"):
            load_regions([{"name": "a"}, {"name": "a"}])
        with pytest.raises(ValueError, match="sum to"):
            assign_replicas(load_regions([{"name": "a", "replicas": 1},
                                          {"name": "b", "replicas": 1}]),
                            3)
        with pytest.raises(ValueError, match="every region"):
            assign_replicas(load_regions([{"name": "a", "replicas": 1},
                                          {"name": "b"}]), 3)

    def test_geo_accounting_closes(self):
        """gCO2 equals energy x the signal's exact mean: with constant
        signals the ledger is checkable in closed form."""
        regs = [{"name": "flat", "carbon": 500.0, "price": 0.20}]
        fl = make_fleet(LLAMA8B, 2, policy="carbon_aware", max_batch=8,
                        regions=regs)
        rep = fl.run(_reqs(40, seed=2, rate=10.0))
        expect_g = rep.total_energy_j * 500.0 / 3.6e6
        expect_usd = rep.total_energy_j * 0.20 / 3.6e6
        assert rep.gco2_total_g == pytest.approx(expect_g, rel=1e-6)
        assert rep.usd_total == pytest.approx(expect_usd, rel=1e-6)
        assert rep.gco2_per_request_g == pytest.approx(expect_g / 40,
                                                       rel=1e-6)

    def test_carbon_router_prefers_low_carbon_region(self):
        regs = [{"name": "dirty", "carbon": 600.0, "replicas": 2},
                {"name": "clean", "carbon": 100.0, "replicas": 2}]
        fl = make_fleet(LLAMA8B, 4, policy="carbon_aware", max_batch=8,
                        regions=regs)
        rep = fl.run(_reqs(30, seed=4, rate=2.0))
        per = rep.requests_per_replica
        assert sum(per[2:]) > sum(per[:2])

    def test_rtt_shifts_client_latency(self):
        regs = [{"name": "far", "rtt_s": 0.5}]
        fl = make_fleet(LLAMA8B, 2, policy="carbon_aware", max_batch=8,
                        regions=regs)
        rep = fl.run(_reqs(20, seed=6, rate=5.0))
        lat = rep.latency_percentiles()["p50"]
        client = rep.client_latency_percentiles()["p50"]
        assert client == pytest.approx(lat + 0.5)

    def test_signal_router_needs_regions(self):
        r = make_router("carbon_aware")
        with pytest.raises(ValueError, match="region"):
            r.select(None, [], 0.0)


class TestDiurnalArrivals:
    def test_basic_properties(self):
        arr = diurnal_arrivals(500, 5.0, period_s=600.0, seed=1)
        assert len(arr) == 500
        assert arr == sorted(arr)
        assert arr[0] >= 0.0

    def test_rate_follows_the_sine(self):
        """First half-period (sin > 0) must receive more arrivals than
        the second (sin < 0)."""
        n = 4000
        arr = np.asarray(diurnal_arrivals(n, 4.0, period_s=1000.0,
                                          amp_frac=0.8, seed=2))
        arr = arr[arr < 1000.0]
        peak = np.sum(arr < 500.0)
        trough = arr.size - peak
        assert peak > 2.0 * trough

    def test_bursts_are_simultaneous(self):
        arr = diurnal_arrivals(400, 5.0, period_s=300.0,
                               bursts_per_day=4.0, burst_size=16,
                               seed=3)
        assert len(arr) == 400
        _, counts = np.unique(np.asarray(arr), return_counts=True)
        assert counts.max() >= 16

    def test_validation(self):
        with pytest.raises(ValueError, match="positive"):
            diurnal_arrivals(10, 0.0)
        with pytest.raises(ValueError, match="amp_frac"):
            diurnal_arrivals(10, 1.0, amp_frac=1.0)
        assert diurnal_arrivals(0, 1.0) == []


class TestFleetSpec:
    def test_defaults_keep_serialization(self):
        s = ExperimentSpec(model="llama-3.1-8b", pipeline="serve",
                           mode="continuous", n_requests=10, replicas=2)
        d = json.loads(s.to_json())
        for k in ("fleet", "autoscaler", "autoscaler_params", "regions"):
            assert k not in d

    def test_fleet_spec_round_trips(self):
        s = ExperimentSpec(
            model="llama-3.1-8b", pipeline="serve", mode="continuous",
            n_requests=10, replicas=4, router="carbon_aware",
            regions=[sinusoid_region("us", replicas=2),
                     sinusoid_region("eu", phase_h=9.0, replicas=2)],
            autoscaler="queue_depth",
            autoscaler_params={"high": 16.0}, arrival="diurnal",
            arrival_params={"base_rate_per_s": 5.0})
        s2 = ExperimentSpec.from_json(s.to_json())
        assert s2.spec_hash() == s.spec_hash()

    @pytest.mark.parametrize("kw,msg", [
        (dict(router="carbon_aware"), "region"),
        (dict(fleet="legacy", autoscaler="target_util"), "legacy"),
        (dict(autoscaler_params={"high": 3.0}), "autoscaler"),
        (dict(fleet="wat"), "fleet"),
        (dict(mode="sequential", fleet="vector"), "continuous"),
        (dict(autoscaler="nope"), "unknown autoscaler"),
    ])
    def test_validation(self, kw, msg):
        base = dict(model="llama-3.1-8b", pipeline="serve",
                    mode="continuous", n_requests=10, replicas=2)
        base.update(kw)
        with pytest.raises(ValueError, match=msg):
            ExperimentSpec(**base)

    def test_geo_run_populates_fleet_fields(self):
        s = ExperimentSpec(
            model="llama-3.1-8b", pipeline="serve", mode="continuous",
            n_requests=60, replicas=2, router="price_aware",
            regions=[sinusoid_region("us", replicas=1),
                     sinusoid_region("eu", phase_h=12.0, replicas=1)],
            arrival="poisson", arrival_params={"rate_per_s": 8.0},
            seed=9)
        d = s.run().to_dict()
        for k in ("gco2_total_g", "gco2_per_request_g", "usd_total",
                  "usd_per_request", "client_latency_p99_s"):
            assert k in d and d[k] is not None
