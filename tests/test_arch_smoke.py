"""Per-architecture smoke tests (assignment requirement f).

For each of the 10 assigned architectures: instantiate the REDUCED
variant of the same family (2 layers, d_model<=512, <=4 experts), run one
forward pass and one train step on CPU, assert output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import batch_for
from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.training import adamw_init, make_train_step, AdamWConfig

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_no_nan(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, fmt="float32")
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = batch_for(cfg, toks)
    h, aux = m.forward_train(params, batch)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    assert h.shape == (B, S + extra, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())
    logits = m.logits(params, h[:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, fmt="float32")
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=1e-3,
                                                  warmup_steps=2)))
    B, S = 2, 16
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (B, S + 1))
    batch = batch_for(cfg, jnp.asarray(toks[:, :-1], jnp.int32))
    batch["labels"] = jnp.asarray(toks[:, 1:], jnp.int32)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["lm_loss"]))
    assert float(metrics["lm_loss"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, fmt="float32")
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    logits, cache = m.prefill(params, batch_for(cfg, toks),
                              buf_len=S + 8 + extra)
    assert logits.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = m.decode_step(params, nxt, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())


def test_param_counts_match_assignment_scale():
    """Full configs should land near their nameplate sizes."""
    expected = {
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "stablelm-1.6b": (1.4e9, 2.0e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "phi-3-vision-4.2b": (3.6e9, 4.4e9),
        "granite-moe-1b-a400m": (1.0e9, 1.5e9),
        "zamba2-1.2b": (1.0e9, 1.5e9),
        "command-r-35b": (30e9, 37e9),
        # untied embed+unembed at 256k vocab adds ~2.1B over the 8B body
        "minitron-8b": (7.5e9, 10.5e9),
        "h2o-danube-3-4b": (3.5e9, 4.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                              f"{hi/1e9}]B"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.param_count(active_only=True)
    total = cfg.param_count()
    assert active < total / 8           # 8/128 experts active
    assert 2.5e9 < active < 4.5e9       # "A3B"
