import os
import sys

# tests see the single real CPU device (the 512-device override is
# strictly limited to the dry-run launcher, per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# CI installs real hypothesis (pyproject [test] extra); the dev container
# cannot, so fall back to the deterministic sampler in
# tests/_hypothesis_fallback.py to keep property tests collectable.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import build_module

    _mod = build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def batch_for(cfg, tokens, seed: int = 5):
    """Build a model input batch for any family."""
    b = {"tokens": tokens}
    B, S = tokens.shape
    if cfg.family == "vlm":
        b["patches"] = (jax.random.normal(
            jax.random.PRNGKey(seed), (B, cfg.num_patches, cfg.d_model))
            * 0.1).astype(jnp.bfloat16)
    if cfg.family == "audio":
        b["frames"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, max(S // cfg.enc_frames_ratio, 1), cfg.d_model))
            * 0.1).astype(jnp.bfloat16)
    return b
