"""Cluster serving sweep (routing policy x replica count x arrival
pattern) as a declarative grid over :class:`repro.ExperimentSpec`.

Fleet-level extension of Fig 3: the single-device result (orchestration
dominates per-request energy) compounds across replicas — a router that
spreads bursty traffic keeps every replica warm at low batch (worst of
both worlds), while the energy-aware policy consolidates load onto few
warm replicas, batches them well, and power-gates the rest.

Claims validated (same rows as ever, via declarative `repro.Claim`s):
* energy-aware routing beats round-robin on mean Wh/request for bursty
  arrivals on 4- and 2-replica fleets,
* it also beats round-robin WITH idle gating (``round_robin_gated``),
  so the win is consolidation/batching quality, not just the gated-
  power discount,
* energy-aware is never worse than round-robin on the steady fixed-
  interval workload,
* a heterogeneous fleet (bf16 + fp32 replicas) routed energy-aware
  beats round-robin on the same bursty workload.

Environment knobs (CI smoke / quick mode):
* ``REPRO_CLUSTER_NREQ``    — requests per scenario (default 240).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_REQ = int(os.environ.get("REPRO_CLUSTER_NREQ", "240"))
# round_robin_gated spreads like round_robin but gates idle replicas —
# it isolates the gating discount from routing quality, so the
# energy_aware-vs-gated-rr claim shows consolidation matters beyond
# gating alone
POLICIES = ("round_robin", "round_robin_gated", "least_loaded",
            "shortest_work", "energy_aware")

BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", max_batch=32, n_requests=N_REQ)

ARRIVAL_AXIS = [
    Option("burst", arrival="burst",
           arrival_params={"burst_size": max(N_REQ // 10, 1),
                           "burst_gap_s": 4.0}),
    Option("poisson_5rps", arrival="poisson",
           arrival_params={"rate_per_s": 5.0, "seed": 0}),
    Option("fixed_100ms", arrival="fixed",
           arrival_params={"interval_s": 0.1}),
]

CLAIMS = (
    Claim("energy_aware_beats_rr_bursty_4rep",
          ratio_of=("round_robin/4rep/burst", "energy_aware/4rep/burst"),
          op=">", threshold=1.0),
    Claim("energy_aware_beats_rr_bursty_2rep",
          ratio_of=("round_robin/2rep/burst", "energy_aware/2rep/burst"),
          op=">", threshold=1.0),
    # beats round-robin WITH gating too: routing/consolidation quality,
    # not just the gated-power discount
    Claim("energy_aware_beats_gated_rr_bursty_4rep",
          ratio_of=("round_robin_gated/4rep/burst",
                    "energy_aware/4rep/burst"),
          op=">", threshold=1.0),
    Claim("energy_aware_no_worse_steady",
          ratio_of=("round_robin/4rep/fixed_100ms",
                    "energy_aware/4rep/fixed_100ms"),
          threshold=1.0 / 1.02),
    Claim("hetero_energy_aware_beats_rr",
          ratio_of=("hetero/round_robin/4rep/burst",
                    "hetero/energy_aware/4rep/burst"),
          op=">", threshold=1.0),
)


def run() -> List[Row]:
    res = sweep(BASE, {
        "router": [Option(p, router=p) for p in POLICIES],
        "replicas": [Option(f"{n}rep", replicas=n) for n in (2, 4)],
        "arrival": ARRIVAL_AXIS,
    })

    # heterogeneous fleet: half bf16, half fp32 replicas — the energy-
    # aware router should steer work to the cheaper bf16 replicas
    hetero = BASE.derive(
        replicas=4,
        replica_overrides=({"fmt": "bfloat16"}, {"fmt": "bfloat16"},
                           {"fmt": "float32"}, {"fmt": "float32"}))
    res = res.merge(sweep(hetero, {
        "router": [Option(p, router=p)
                   for p in ("round_robin", "energy_aware")],
        "replicas": [Option("4rep")],
        "arrival": [ARRIVAL_AXIS[0]],
    }, tag="hetero"))
    res.check(CLAIMS)

    rows = [Row(name=f"cluster/{label}",
                us_per_call=r.latency_p50_s * 1e6,
                derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                         f"util={r.utilization:.2f} "
                         f"gatedJ={r.gated_energy_j:.0f} "
                         f"p99={r.latency_p99_s:.2f}s"),
                spec_hash=r.spec_hash)
            for label, r in res.results.items()]
    rows += claim_rows(res.claims)
    save_sweep("cluster", res)
    return rows
