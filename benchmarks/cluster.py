"""Cluster serving sweep: routing policy x replica count x arrival
pattern, on the paper's LLaMA-3.1-8B workload.

Fleet-level extension of Fig 3: the single-device result (orchestration
dominates per-request energy) compounds across replicas — a router that
spreads bursty traffic keeps every replica warm at low batch (worst of
both worlds), while the energy-aware policy consolidates load onto few
warm replicas, batches them well, and power-gates the rest.

Claims validated:
* energy-aware routing beats round-robin on mean Wh/request for bursty
  arrivals on a 4-replica fleet (the consolidation + gating win),
* it also beats round-robin WITH idle gating (``round_robin_gated``),
  so the win is consolidation/batching quality, not just the gated-
  power discount,
* energy-aware is never worse than round-robin on the steady fixed-
  interval workload (consolidation cannot lose when spreading is
  already optimal-ish),
* a heterogeneous fleet (bf16 + fp32 replicas) routed energy-aware
  beats round-robin on the same bursty workload (the router also picks
  the cheaper format).

Environment knobs (CI smoke / quick mode):
* ``REPRO_CLUSTER_NREQ``    — requests per scenario (default 240).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import (PAPER_MODELS, Row, paper_requests,
                               save_results)
from repro.serving import (ClusterEngine, ServeEngine, burst_arrivals,
                           fixed_arrivals, make_cluster, make_router,
                           poisson_arrivals)

N_REQ = int(os.environ.get("REPRO_CLUSTER_NREQ", "240"))
# round_robin_gated spreads like round_robin but gates idle replicas —
# it isolates the gating discount from routing quality, so the
# energy_aware-vs-gated-rr claim shows consolidation matters beyond
# gating alone
POLICIES = ("round_robin", "round_robin_gated", "least_loaded",
            "shortest_work", "energy_aware")
REPLICAS = (2, 4)


def _arrival_grid(n: int):
    return {
        "burst": burst_arrivals(n, max(n // 10, 1), 4.0),
        "poisson_5rps": poisson_arrivals(n, rate_per_s=5.0, seed=0),
        "fixed_100ms": fixed_arrivals(n, 0.1),
    }


def run() -> List[Row]:
    cfg = PAPER_MODELS["llama-3.1-8b"]
    rows: List[Row] = []
    results = {}

    def record(name: str, rep) -> None:
        s = rep.summary()
        results[name] = s
        rows.append(Row(
            name=f"cluster/{name}",
            us_per_call=s["latency_p50_s"] * 1e6,
            derived=(f"Wh/req={s['mean_energy_wh']:.5f} "
                     f"util={s['mean_utilization']:.2f} "
                     f"gatedJ={s['gated_energy_j']:.0f} "
                     f"p99={s['latency_p99_s']:.2f}s")))

    for n_rep in REPLICAS:
        for arr_name, arrivals in _arrival_grid(N_REQ).items():
            for policy in POLICIES:
                cl = make_cluster(cfg, n_rep, policy=policy,
                                  max_batch=32)
                rep = cl.run(paper_requests(N_REQ, arrivals))
                record(f"{policy}/{n_rep}rep/{arr_name}", rep)

    # heterogeneous fleet: half bf16, half fp32 replicas — the energy-
    # aware router should steer work to the cheaper bf16 replicas
    def _hetero(policy: str) -> ClusterEngine:
        fleet = [ServeEngine(cfg, fmt="bfloat16", mode="continuous",
                             max_batch=32) for _ in range(2)]
        fleet += [ServeEngine(cfg, fmt="float32", mode="continuous",
                              max_batch=32) for _ in range(2)]
        return ClusterEngine(fleet, make_router(policy))

    arrivals = _arrival_grid(N_REQ)["burst"]
    for policy in ("round_robin", "energy_aware"):
        record(f"hetero/{policy}/4rep/burst",
               _hetero(policy).run(paper_requests(N_REQ, arrivals)))

    def wh(name: str) -> float:
        return results[name]["mean_energy_wh"]

    checks = {
        "energy_aware_beats_rr_bursty_4rep": (
            wh("round_robin/4rep/burst")
            / wh("energy_aware/4rep/burst"),
            wh("energy_aware/4rep/burst")
            < wh("round_robin/4rep/burst")),
        "energy_aware_beats_rr_bursty_2rep": (
            wh("round_robin/2rep/burst")
            / wh("energy_aware/2rep/burst"),
            wh("energy_aware/2rep/burst")
            < wh("round_robin/2rep/burst")),
        # beats round-robin WITH gating too: routing/consolidation
        # quality, not just the gated-power discount
        "energy_aware_beats_gated_rr_bursty_4rep": (
            wh("round_robin_gated/4rep/burst")
            / wh("energy_aware/4rep/burst"),
            wh("energy_aware/4rep/burst")
            < wh("round_robin_gated/4rep/burst")),
        "energy_aware_no_worse_steady": (
            wh("round_robin/4rep/fixed_100ms")
            / wh("energy_aware/4rep/fixed_100ms"),
            wh("energy_aware/4rep/fixed_100ms")
            <= wh("round_robin/4rep/fixed_100ms") * 1.02),
        "hetero_energy_aware_beats_rr": (
            wh("hetero/round_robin/4rep/burst")
            / wh("hetero/energy_aware/4rep/burst"),
            wh("hetero/energy_aware/4rep/burst")
            < wh("hetero/round_robin/4rep/burst")),
    }
    for k, (v, ok) in checks.items():
        rows.append(Row(name=f"claim/{k}", us_per_call=0.0,
                        derived=f"value={v:.2f} pass={ok}"))
    save_results("cluster", [{"results": results,
                              "checks": {k: [float(v), bool(ok)]
                                         for k, (v, ok)
                                         in checks.items()}}])
    return rows
