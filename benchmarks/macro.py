"""Paper §6 macro impact estimate: serving LLaMA-8B at 1M requests/day,
as a two-point declarative sweep.

naive (fp32, no batching, eager)  vs  optimized (bf16 + continuous
batching + best fixed arrival spacing).
Claim: >= 20x total-energy reduction on the §2 workload (the paper's
>100x headline requires the short-prompt regime — the per-request
prefill-compute floor analysis in EXPERIMENTS.md §Validation caps the
§2-workload ratio near ~30x).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_REQ = 300
REQ_PER_DAY = 1e6

BASE = ExperimentSpec(model="llama-3.1-8b", n_requests=N_REQ)

CLAIMS = (
    Claim("macro_reduction_ge_20x", ratio_of=("naive", "optimized"),
          threshold=20.0),
)


def run() -> List[Row]:
    res = sweep(BASE, {"config": [
        Option("naive", fmt="float32", mode="sequential"),
        Option("optimized", fmt="bfloat16", mode="continuous",
               max_batch=64, arrival="fixed",
               arrival_params={"interval_s": 0.01}),
    ]}, claims=CLAIMS)

    def kwh_day(label: str) -> float:
        return res[label].mean_energy_wh * REQ_PER_DAY / 1e3

    rows = [
        Row("macro/naive_fp32_kwh_per_day", 0.0,
            f"{kwh_day('naive'):.1f} kWh/day (paper: 1.2e2)",
            spec_hash=res["naive"].spec_hash),
        Row("macro/optimized_kwh_per_day", 0.0,
            f"{kwh_day('optimized'):.2f} kWh/day (paper: 1.1e0)",
            spec_hash=res["optimized"].spec_hash),
    ]
    rows += claim_rows(res.claims)
    save_sweep("macro", res)
    return rows
