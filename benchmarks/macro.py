"""Paper §6 macro impact estimate: serving LLaMA-8B at 1M requests/day,
as a two-point declarative sweep — plus the fleet-scale version the
event-horizon simulator makes feasible.

naive (fp32, no batching, eager)  vs  optimized (bf16 + continuous
batching + best fixed arrival spacing).
Claim: >= 20x total-energy reduction on the §2 workload (the paper's
>100x headline requires the short-prompt regime — the per-request
prefill-compute floor analysis in EXPERIMENTS.md §Validation caps the
§2-workload ratio near ~30x).

The ``fleet`` scenario co-simulates an actual day-scale request count —
one million requests, batched in bursts across a 4-replica fleet —
instead of extrapolating 300 requests to 1M. Single-stepping this
point costs hours of host time (one Python iteration per decoded
token); macro-stepping completes it in minutes (see
``benchmarks/simperf.py``), which is why it could not ship before.
``REPRO_MACRO_FLEET_NREQ`` shrinks it for CI smoke (``--quick`` sets
20k).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_REQ = 300
REQ_PER_DAY = 1e6
FLEET_NREQ = int(os.environ.get("REPRO_MACRO_FLEET_NREQ", "1000000"))

BASE = ExperimentSpec(model="llama-3.1-8b", n_requests=N_REQ)

#: 1M requests in consolidation-friendly bursts over four replicas —
#: the serving regime the paper's §5 shaping result says to aim for
FLEET = ExperimentSpec(
    model="llama-3.1-8b", n_requests=FLEET_NREQ,
    replicas=4, router="least_loaded", max_batch=64,
    arrival="burst",
    arrival_params={"burst_size": 1000, "burst_gap_s": 20.0})

CLAIMS = (
    Claim("macro_reduction_ge_20x", ratio_of=("naive", "optimized"),
          threshold=20.0),
    # the fleet co-simulation must actually serve every request —
    # nothing shed, and the completed-token mass at least the
    # workload's 10-token-per-request floor (tokens_per_s counts
    # completed requests only, so truncated/lost requests fail this) —
    # at a deep mean batch (the consolidation the bursts are for),
    # keeping the bulk of the naive baseline's reduction even with
    # real idle gaps and four replicas' worth of idle power
    Claim("fleet_nothing_shed", value_of="fleet", metric="n_shed",
          op="<=", threshold=0.0),
    Claim("fleet_tokens_served",
          value_fn=lambda res: (res["fleet"].tokens_per_s
                                * res["fleet"].wall_time_s),
          op=">=", threshold=10.0 * FLEET_NREQ),
    Claim("fleet_mean_batch_ge_16", value_of="fleet",
          metric="mean_batch", op=">=", threshold=16.0),
    Claim("fleet_reduction_ge_10x", ratio_of=("naive", "fleet"),
          threshold=10.0),
)


def run() -> List[Row]:
    res = sweep(BASE, {"config": [
        Option("naive", fmt="float32", mode="sequential"),
        Option("optimized", fmt="bfloat16", mode="continuous",
               max_batch=64, arrival="fixed",
               arrival_params={"interval_s": 0.01}),
    ]})
    res = res.merge(sweep(FLEET, tag="fleet"))
    res.check(CLAIMS)

    def kwh_day(label: str) -> float:
        return res[label].mean_energy_wh * REQ_PER_DAY / 1e3

    fleet = res["fleet"]
    rows = [
        Row("macro/naive_fp32_kwh_per_day", 0.0,
            f"{kwh_day('naive'):.1f} kWh/day (paper: 1.2e2)",
            spec_hash=res["naive"].spec_hash),
        Row("macro/optimized_kwh_per_day", 0.0,
            f"{kwh_day('optimized'):.2f} kWh/day (paper: 1.1e0)",
            spec_hash=res["optimized"].spec_hash),
        Row("macro/fleet_kwh_per_day", 0.0,
            f"{kwh_day('fleet'):.2f} kWh/day co-simulated "
            f"({fleet.n_requests} req x {fleet.replicas} replicas "
            f"batch {fleet.mean_batch:.0f})",
            spec_hash=fleet.spec_hash),
    ]
    rows += claim_rows(res.claims)
    save_sweep("macro", res)
    return rows
