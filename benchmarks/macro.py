"""Paper §6 macro impact estimate: serving LLaMA-8B at 1M requests/day.

naive (fp32, no batching, eager)  vs  optimized (bf16 + continuous
batching + best fixed arrival spacing).
Claim: >= 20x total-energy reduction on the §2 workload (the paper's
>100x headline requires the short-prompt regime — the per-request
prefill-compute floor analysis in EXPERIMENTS.md §Validation caps the
§2-workload ratio near ~30x).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import PAPER_MODELS, Row, save_results
from repro.serving import ServeEngine, Request, fixed_arrivals
from repro.training.data import RequestDistribution

N_REQ = 300
REQ_PER_DAY = 1e6


def _requests(n, arrivals, seed=0):
    dist = RequestDistribution(seed=seed)
    out = []
    for i in range(n):
        s = dist.sample()
        out.append(Request(req_id=i, prompt=None, prompt_len=s.prompt_len,
                           max_new_tokens=s.output_len,
                           arrival_time=arrivals[i]))
    return out


def run() -> List[Row]:
    cfg = PAPER_MODELS["llama-3.1-8b"]
    naive = ServeEngine(cfg, fmt="float32", mode="sequential").run(
        _requests(N_REQ, [0.0] * N_REQ))
    opt = ServeEngine(cfg, fmt="bfloat16", mode="continuous",
                      max_batch=64).run(
        _requests(N_REQ, fixed_arrivals(N_REQ, 0.01)))
    naive_kwh_day = (naive.mean_energy_per_request_wh * REQ_PER_DAY
                     / 1e3)
    opt_kwh_day = opt.mean_energy_per_request_wh * REQ_PER_DAY / 1e3
    reduction = naive_kwh_day / opt_kwh_day
    rows = [
        Row("macro/naive_fp32_kwh_per_day", 0.0,
            f"{naive_kwh_day:.1f} kWh/day (paper: 1.2e2)"),
        Row("macro/optimized_kwh_per_day", 0.0,
            f"{opt_kwh_day:.2f} kWh/day (paper: 1.1e0)"),
        Row("claim/macro_reduction_ge_20x", 0.0,
            f"value={reduction:.1f} pass={reduction >= 20}"),
    ]
    save_results("macro", [{"naive_kwh_day": naive_kwh_day,
                            "opt_kwh_day": opt_kwh_day,
                            "reduction": reduction,
                            "pass": bool(reduction >= 20)}])
    return rows
