"""Paper Fig. 1a/1b (energy by dtype x model, prefill/decode) and
Fig. 4/5 (latency by dtype), as a declarative profile-pipeline sweep
(model x precision format) over :class:`repro.ExperimentSpec`.

Claims validated (same rows as ever, via declarative `repro.Claim`s):
* prefill: >=2.5x GPU-energy reduction fp32 -> bf16 for the largest
  models; small models gain much less (<2x),
* prefill latency gain exceeds energy gain (Tensor Core power draw),
* decode: fp16/bf16 within ~35% of fp32 (invariance); int8 >= 1.7x
  WORSE than fp32; int4 within ~40% of fp32,
* the FusedDequantEnergyModel (our Pallas TPU path) removes the int8
  decode penalty — the beyond-paper result.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (PAPER_MODELS, PAPER_OUTPUT_MEAN,
                               PAPER_PROMPT_MEAN, Row, claim_rows,
                               save_sweep)
from repro import Claim, ExperimentSpec, Option, sweep

FORMATS = ("float32", "float16", "bfloat16", "int8", "nf4")
MODELS = tuple(m for m in PAPER_MODELS if m != "llama-3.1-70b")

#: one profiled prefill+decode point per (model, fmt): batch 1, the §3.1
#: mean prompt, the §2 mean output length
BASE = ExperimentSpec(pipeline="profile", max_batch=1,
                      prompt_range=(PAPER_PROMPT_MEAN, PAPER_PROMPT_MEAN),
                      output_range=(PAPER_OUTPUT_MEAN, PAPER_OUTPUT_MEAN))


def _gain(rs, model: str, metric: str) -> float:
    return (rs[f"model={model}/fmt=float32"].metric(metric)
            / rs[f"model={model}/fmt=bfloat16"].metric(metric))


CLAIMS = (
    Claim("prefill_gain_large_fp32_to_bf16",
          ratio_of=("model=qwen2.5-14b/fmt=float32",
                    "model=qwen2.5-14b/fmt=bfloat16"),
          metric="prefill_energy_j", threshold=2.5),
    Claim("prefill_gain_small_lt_large",
          value_fn=lambda rs: _gain(rs, "qwen2.5-0.5b",
                                    "prefill_energy_j"),
          op=">", threshold=0.0,
          where=lambda rs: (_gain(rs, "qwen2.5-0.5b", "prefill_energy_j")
                            < _gain(rs, "qwen2.5-14b",
                                    "prefill_energy_j"))),
    Claim("prefill_latency_gain_gt_energy_gain",
          value_fn=lambda rs: _gain(rs, "qwen2.5-14b",
                                    "prefill_latency_s"),
          op=">", threshold=0.0,
          where=lambda rs: (_gain(rs, "qwen2.5-14b", "prefill_latency_s")
                            > _gain(rs, "qwen2.5-14b",
                                    "prefill_energy_j"))),
    Claim("decode_16bit_near_invariant",
          ratio_of=("model=llama-3.1-8b/fmt=bfloat16",
                    "model=llama-3.1-8b/fmt=float32"),
          metric="decode_j_per_tok", op="range", threshold=(0.5, 1.1)),
    Claim("decode_int8_penalty",
          ratio_of=("model=llama-3.1-8b/fmt=int8",
                    "model=llama-3.1-8b/fmt=float32"),
          metric="decode_j_per_tok", threshold=1.7),
    Claim("decode_int4_similar_to_fp32",
          ratio_of=("model=llama-3.1-8b/fmt=nf4",
                    "model=llama-3.1-8b/fmt=float32"),
          metric="decode_j_per_tok", op="range", threshold=(0.6, 1.5)),
    # beyond-paper: fused TPU dequant removes the int8 penalty
    Claim("beyond_paper_fused_int8_beats_bf16",
          ratio_of=("fused/int8_fused_dequant", "fused/bf16"),
          metric="decode_j_per_tok", op="<", threshold=1.0),
)


def run() -> List[Row]:
    res = sweep(BASE, {"model": list(MODELS), "fmt": list(FORMATS)})

    # beyond-paper point: our Pallas TPU fused-dequant path, int8 vs
    # bf16 decode on the fused serving stack
    fused = BASE.derive(model="llama-3.1-8b", device="tpu-v5e",
                        stack="fused", output_range=(64, 64))
    res = res.merge(sweep(fused, {"fmt": [
        Option("int8_fused_dequant", fmt="int8",
               energy_model="fused_dequant"),
        Option("bf16", fmt="bfloat16"),
    ]}, tag="fused"))
    res.check(CLAIMS)

    rows: List[Row] = []
    for label, r in res.results.items():
        model_fmt = label.replace("model=", "").replace("fmt=", "")
        rows.append(Row(
            name=f"fig1a_prefill/{model_fmt}",
            us_per_call=r.prefill_latency_s * 1e6,
            derived=(f"E={r.prefill_energy_j:.2f}J "
                     f"bound={r.prefill_bound}"),
            spec_hash=r.spec_hash))
        rows.append(Row(
            name=f"fig1b_decode/{model_fmt}",
            us_per_call=r.decode_ms_per_tok * 1e3,
            derived=(f"E/tok={r.decode_j_per_tok:.2f}J "
                     f"bound={r.decode_bound}"),
            spec_hash=r.spec_hash))
    rows += claim_rows(res.claims)
    save_sweep("precision", res)
    return rows
