"""Paper Fig. 1a/1b (energy by dtype x model, prefill/decode) and
Fig. 4/5 (latency by dtype).

Claims validated:
* prefill: >=2.5x GPU-energy reduction fp32 -> bf16 for the largest
  models; small models gain much less (<2x),
* prefill latency gain exceeds energy gain (Tensor Core power draw),
* decode: fp16/bf16 within ~35% of fp32 (invariance); int8 >= 1.7x
  WORSE than fp32; int4 within ~40% of fp32,
* the FusedDequantEnergyModel (our Pallas TPU path) removes the int8
  decode penalty — the beyond-paper result.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (PAPER_MODELS, PAPER_PROMPT_MEAN,
                               PAPER_OUTPUT_MEAN, Row, save_results)
from repro.core import (PhaseProfiler, make_policy, H100_SXM, TPU_V5E,
                        FusedDequantEnergyModel)

FORMATS = ("float32", "float16", "bfloat16", "int8", "nf4")


def run() -> List[Row]:
    rows: List[Row] = []
    data = []
    for mname, cfg in PAPER_MODELS.items():
        if mname == "llama-3.1-70b":
            continue
        rec = {"model": mname}
        for fmt in FORMATS:
            prof = PhaseProfiler(cfg, H100_SXM, make_policy(fmt))
            pre = prof.profile_prefill(1, PAPER_PROMPT_MEAN)
            dec = prof.profile_decode(1, PAPER_PROMPT_MEAN,
                                      PAPER_OUTPUT_MEAN) \
                .per(PAPER_OUTPUT_MEAN)
            rec[fmt] = {
                "prefill_J": pre.energy_j,
                "prefill_ms": pre.latency * 1e3,
                "prefill_bound": pre.bound,
                "decode_J_per_tok": dec.energy_j,
                "decode_ms_per_tok": dec.latency * 1e3,
                "decode_bound": dec.bound,
            }
            rows.append(Row(
                name=f"fig1a_prefill/{mname}/{fmt}",
                us_per_call=pre.latency * 1e6,
                derived=f"E={pre.energy_j:.2f}J bound={pre.bound}"))
            rows.append(Row(
                name=f"fig1b_decode/{mname}/{fmt}",
                us_per_call=dec.latency * 1e6,
                derived=f"E/tok={dec.energy_j:.2f}J bound={dec.bound}"))
        data.append(rec)

    # ---- claim checks (paper-faithful baseline) ------------------------
    big = next(r for r in data if r["model"] == "qwen2.5-14b")
    small = next(r for r in data if r["model"] == "qwen2.5-0.5b")
    gain_big = big["float32"]["prefill_J"] / big["bfloat16"]["prefill_J"]
    gain_small = (small["float32"]["prefill_J"]
                  / small["bfloat16"]["prefill_J"])
    lat_big = (big["float32"]["prefill_ms"]
               / big["bfloat16"]["prefill_ms"])
    l8 = next(r for r in data if r["model"] == "llama-3.1-8b")
    dec_inv = l8["bfloat16"]["decode_J_per_tok"] \
        / l8["float32"]["decode_J_per_tok"]
    int8_pen = l8["int8"]["decode_J_per_tok"] \
        / l8["float32"]["decode_J_per_tok"]
    nf4_pen = l8["nf4"]["decode_J_per_tok"] \
        / l8["float32"]["decode_J_per_tok"]
    checks = {
        "prefill_gain_large_fp32_to_bf16": (gain_big, gain_big >= 2.5),
        "prefill_gain_small_lt_large": (gain_small,
                                        gain_small < gain_big),
        "prefill_latency_gain_gt_energy_gain": (lat_big,
                                                lat_big > gain_big),
        "decode_16bit_near_invariant": (dec_inv, 0.5 < dec_inv <= 1.1),
        "decode_int8_penalty": (int8_pen, int8_pen >= 1.7),
        "decode_int4_similar_to_fp32": (nf4_pen, 0.6 < nf4_pen < 1.5),
    }
    # ---- beyond-paper: fused TPU dequant removes the int8 penalty ------
    prof_f = PhaseProfiler(PAPER_MODELS["llama-3.1-8b"], TPU_V5E,
                           make_policy("int8"),
                           energy_model_cls=FusedDequantEnergyModel,
                           stack="fused")
    prof_b = PhaseProfiler(PAPER_MODELS["llama-3.1-8b"], TPU_V5E,
                           make_policy("bfloat16"), stack="fused")
    e_fused = prof_f.profile_decode(1, PAPER_PROMPT_MEAN, 64).per(64)
    e_bf16 = prof_b.profile_decode(1, PAPER_PROMPT_MEAN, 64).per(64)
    fused_ratio = e_fused.energy_j / e_bf16.energy_j
    checks["beyond_paper_fused_int8_beats_bf16"] = (
        fused_ratio, fused_ratio < 1.0)

    for k, (v, ok) in checks.items():
        rows.append(Row(name=f"claim/{k}", us_per_call=0.0,
                        derived=f"value={v:.3f} pass={ok}"))
    save_results("precision", [{"data": data,
                                "checks": {k: [float(v), bool(ok)]
                                           for k, (v, ok)
                                           in checks.items()}}])
    return rows
