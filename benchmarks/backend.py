"""Backend sweep: analytic/replay parity plus the DVFS frequency x
batch energy frontier (declarative grid over `repro.ExperimentSpec`).

The backend axis swaps the *cost source* under an unchanged scheduler
(Ifath & Haque: cross-substrate comparison requires holding the
scheduler fixed), so two things become checkable as claims:

* **replay parity** — recording an analytic run's phase stream
  (`RecordingBackend`) and replaying it (`ReplayBackend`) reproduces
  the analytic report through the live scheduler (round trip ~1.0x),
  and the shipped H100 trace fixture drives the same workload to the
  same energy scale;
* **DVFS frontier** — in the memory-bound decode regime (long outputs,
  deep batch), decode latency rides the HBM clock domain while busy
  power rides the core clock: downclocking (`freq_scale < 1.0`) cuts
  Wh/request ~2x at ~11% p99 cost, at every batch depth. The frontier
  minimum exists because prefill is compute-bound (its latency grows as
  1/f), so the win is a *frequency x phase-mix* property — exactly the
  Fernandez et al. observation that the same workload's energy varies
  strongly with frequency state.

Environment knobs (CI smoke / quick mode):
* ``REPRO_BACKEND_NREQ`` — requests per scenario (default 96).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import RESULTS_DIR, Row, claim_rows, save_sweep
from repro import (AnalyticBackend, Claim, ExperimentSpec, Option,
                   RecordingBackend, run_spec, sweep)
from repro.serving.engine import ServeEngine
from repro.sweep import SweepResult
from repro.batching.policy import SlotCountPolicy

N_REQ = int(os.environ.get("REPRO_BACKEND_NREQ", "96"))
FREQS = (0.5, 0.6, 0.75, 0.9)
FIXTURE = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                       "replay_h100_small.json")

#: memory-bound decode regime: short prompts, long outputs, deep batch
BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", n_requests=N_REQ,
                      prompt_range=(200, 600), output_range=(150, 300))

#: the workload tests/data/replay_h100_small.json was recorded from
FIXTURE_WORKLOAD = ExperimentSpec(
    model="llama-3.1-8b", fmt="bfloat16", mode="continuous",
    max_batch=16, n_requests=48, seed=7, prompt_range=(200, 1200),
    output_range=(20, 120), arrival="burst",
    arrival_params={"burst_size": 12, "burst_gap_s": 5.0})


def _win(rs, batch: int) -> float:
    """Nominal-vs-best-frequency Wh/request ratio at one batch depth."""
    nominal = rs[f"dvfs/nominal/b{batch}"].mean_energy_wh
    best = min(rs[f"dvfs/f{f:g}/b{batch}"].mean_energy_wh
               for f in FREQS)
    return nominal / best


CLAIMS = (
    # the tentpole claim: a sub-nominal frequency point beats 1.0 on
    # Wh/request in the memory-bound decode regime
    Claim("dvfs_frontier_beats_nominal",
          ratio_of=("dvfs/nominal/b32", "dvfs/f*/b32"),
          agg_den="min", threshold=1.5),
    # ... at every batch depth (the frontier is not a batch artifact)
    Claim("dvfs_frontier_all_batches",
          value_fn=lambda rs: min(_win(rs, 8), _win(rs, 32)),
          op=">", threshold=1.0),
    # ... and nearly for free on tail latency (decode latency lives on
    # the HBM clock domain, which DVFS does not touch)
    Claim("dvfs_frontier_cheap_latency",
          value_fn=lambda rs: (rs["dvfs/f0.5/b32"].latency_p99_s
                               / rs["dvfs/nominal/b32"].latency_p99_s),
          op="<=", threshold=1.3),
    # record -> replay round trip reproduces the analytic report
    Claim("replay_roundtrip_parity",
          ratio_of=("replay/roundtrip", "replay/analytic_ref"),
          op="range", threshold=(0.98, 1.02)),
    # the shipped H100 trace fixture drives its source workload to the
    # same energy scale through the live scheduler
    Claim("replay_fixture_vs_analytic",
          ratio_of=("replay/fixture", "replay/fixture_analytic"),
          op="range", threshold=(0.8, 1.25)),
)


def _replay_points() -> SweepResult:
    """The replay scenarios: a same-run round trip plus the shipped
    fixture, each paired with its analytic reference. (`run_spec`
    refuses to memoize replay specs — the spec hash cannot see
    trace-file *content*, only its path.)"""
    ref, ref_hit = run_spec(BASE.derive(max_batch=32))

    # record the reference workload's phase stream, then replay it
    cfg = BASE.model_config()
    rec = RecordingBackend(AnalyticBackend(cfg))
    eng = ServeEngine(cfg, backend=rec, batch_policy=SlotCountPolicy(max_batch=32))
    eng.run(BASE.derive(max_batch=32).requests())
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "replay_roundtrip_trace.json")
    rec.dump(path, device="h100-sxm", model=cfg.name,
             source="benchmarks/backend.py round-trip recording")
    roundtrip, rt_hit = run_spec(
        BASE.derive(max_batch=32, backend="replay", replay_path=path))

    fixture_ref, fr_hit = run_spec(FIXTURE_WORKLOAD)
    fixture, fx_hit = run_spec(
        FIXTURE_WORKLOAD.derive(backend="replay", replay_path=FIXTURE))
    hits = sum([ref_hit, rt_hit, fr_hit, fx_hit])
    return SweepResult(results={
        "replay/analytic_ref": ref,
        "replay/roundtrip": roundtrip,
        "replay/fixture_analytic": fixture_ref,
        "replay/fixture": fixture,
    }, cache_hits=hits, cache_misses=4 - hits)


def run() -> List[Row]:
    res = sweep(BASE, {
        "freq_scale": [Option("nominal"),
                       *[Option(f"f{f:g}", freq_scale=f) for f in FREQS]],
        "max_batch": [Option(f"b{b}", max_batch=b) for b in (8, 32)],
    }, tag="dvfs")
    res = res.merge(_replay_points())
    res.check(CLAIMS)

    rows = [Row(name=f"backend/{label}",
                us_per_call=r.mean_latency_s * 1e6,
                derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                         f"p99={r.latency_p99_s:.2f}s "
                         f"batch={r.mean_batch:.1f} "
                         f"util={r.utilization:.2f}"),
                spec_hash=r.spec_hash)
            for label, r in res.results.items()]
    rows += claim_rows(res.claims)
    save_sweep("backend", res)
    return rows
