"""Real-compute microbenchmarks (CPU wall-time): kernels in interpret
mode vs their jnp references, and one reduced-model serve/train step.
These give honest measured us_per_call numbers alongside the modeled
energy benches."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit, save_results
from repro.configs import get_config
from repro.models import build_model
from repro.quant import quantize_int8
from repro.kernels.quant_matmul.kernel import int8_matmul_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.training import adamw_init, make_train_step


def run() -> List[Row]:
    rows: List[Row] = []
    k = jax.random.PRNGKey(0)

    # int8 kernel vs fused-jnp dequant matmul
    x = jax.random.normal(k, (64, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.05
    q = quantize_int8(w)
    f_kernel = jax.jit(lambda a: int8_matmul_pallas(
        a, q.codes, q.scale, bm=64, bn=256, bk=256))
    f_ref = jax.jit(lambda a: jnp.dot(
        a, q.codes.astype(jnp.float32) * q.scale[None, :]))
    f_kernel(x).block_until_ready()
    f_ref(x).block_until_ready()
    rows.append(Row("micro/int8_kernel_interpret",
                    timeit(lambda: f_kernel(x).block_until_ready()),
                    "pallas interpret mode (CPU emulation)"))
    rows.append(Row("micro/int8_xla_fused",
                    timeit(lambda: f_ref(x).block_until_ready()),
                    "XLA-fused dequant+dot reference"))

    # flash attention kernel vs jnp chunked attention
    B, S, H, Kv, d = 1, 256, 4, 2, 64
    qq = jax.random.normal(k, (B, S, H, d), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, d))
    vv = jax.random.normal(jax.random.PRNGKey(3), (B, S, Kv, d))
    f_fl = jax.jit(lambda a, b, c: flash_attention_pallas(
        a, b, c, bq=64, bkv=64))
    f_fl(qq, kk, vv).block_until_ready()
    rows.append(Row("micro/flash_attention_interpret",
                    timeit(lambda: f_fl(qq, kk, vv).block_until_ready()),
                    f"S={S} causal"))

    # reduced-model serve + train step wall time
    cfg = get_config("minitron-8b").reduced()
    m = build_model(cfg, fmt="float32")
    params = m.init(k)
    toks = jnp.zeros((2, 32), jnp.int32)
    _, cache = m.prefill(params, {"tokens": toks}, buf_len=64)
    step_tok = jnp.ones((2, 1), jnp.int32)
    dec = jax.jit(m.decode_step)
    dec(params, step_tok, cache)[0].block_until_ready()
    rows.append(Row("micro/reduced_decode_step",
                    timeit(lambda: dec(params, step_tok,
                                       cache)[0].block_until_ready()),
                    f"{cfg.name}"))
    tstep = jax.jit(make_train_step(m))
    opt = adamw_init(params)
    batch = {"tokens": toks, "labels": toks}
    out = tstep(params, opt, batch)
    out[2]["lm_loss"].block_until_ready()
    rows.append(Row("micro/reduced_train_step",
                    timeit(lambda: tstep(params, opt, batch)[2]
                           ["lm_loss"].block_until_ready()),
                    f"{cfg.name}"))
    save_results("microbench", [r.__dict__ for r in rows])
    return rows
