"""Shared benchmark infrastructure.

The paper's model zoo and the §2/§3.1 request sampler live in ``src``
(`repro.configs.paper_zoo.PAPER_MODELS`,
`repro.serving.arrival.paper_requests`) — re-exported here so older
callers keep working. The benchmarks themselves are declarative sweeps
over :class:`repro.ExperimentSpec` (see `repro.sweep`); this module
keeps the CSV row schema and the result-dump helpers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Iterable, List

# single sources of truth in src (re-exported for compatibility)
from repro.configs.paper_zoo import PAPER_MODELS  # noqa: F401
from repro.serving.arrival import paper_requests  # noqa: F401
from repro.sweep import ClaimResult, SweepResult

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "bench")

PAPER_PROMPT_MEAN = 1200        # §3.1: s_mean ~ 1200
PAPER_OUTPUT_MEAN = 80          # §2: outputs 10-300, chat-like


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    spec_hash: str = ""         # provenance: ExperimentSpec content hash

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def claim_rows(claims: Iterable[ClaimResult]) -> List[Row]:
    """One ``claim/...`` row per declarative claim verdict (the schema
    run.py's exit code and the CI gate key on)."""
    return [Row(name=f"claim/{c.name}", us_per_call=0.0,
                derived=f"value={c.value:.2f} pass={c.passed}")
            for c in claims]


def sweep_summary(res: SweepResult) -> Dict[str, Dict]:
    """results-dict view of a sweep (label -> flat record) for
    :func:`save_results`."""
    return {label: r.to_dict() for label, r in res.results.items()}


def save_results(bench: str, rows: List[Dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, bench + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def save_sweep(bench: str, res: SweepResult) -> None:
    """Standard dump for a sweep-based benchmark: per-label records plus
    claim verdicts."""
    save_results(bench, [{
        "results": sweep_summary(res),
        "checks": {c.name: [float(c.value), bool(c.passed)]
                   for c in res.claims},
        "cache": {"hits": res.cache_hits, "misses": res.cache_misses},
    }])


def timeit(fn: Callable, n: int = 3) -> float:
    """Median wall-time of fn() in microseconds."""
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
