"""Shared benchmark infrastructure.

Defines the paper's model zoo (§2: Qwen-2.5 0.5–14B, Mistral-7B,
LLaMA-3.1-8B/70B) as ModelConfigs, plus CSV/reporting helpers. Energy
numbers come from the phase-aware analytic model on H100 constants
(the paper's measurement platform); latency micro-measurements for the
real-compute benches run reduced models on CPU.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List

from repro.configs.base import ModelConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "bench")


def _dense(name, L, d, H, kv, ff, V=151936) -> ModelConfig:
    return ModelConfig(name=name, family="dense", num_layers=L, d_model=d,
                       num_heads=H, num_kv_heads=kv, d_ff=ff, vocab_size=V,
                       source="paper §2 benchmark zoo")


# the paper's §2 model selection
PAPER_MODELS: Dict[str, ModelConfig] = {
    "qwen2.5-0.5b": _dense("qwen2.5-0.5b", 24, 896, 14, 2, 4864),
    "qwen2.5-1.5b": _dense("qwen2.5-1.5b", 28, 1536, 12, 2, 8960),
    "qwen2.5-3b": _dense("qwen2.5-3b", 36, 2048, 16, 2, 11008),
    "qwen2.5-7b": _dense("qwen2.5-7b", 28, 3584, 28, 4, 18944),
    "qwen2.5-14b": _dense("qwen2.5-14b", 48, 5120, 40, 8, 13824),
    "mistral-7b": _dense("mistral-7b", 32, 4096, 32, 8, 14336, 32768),
    "llama-3.1-8b": _dense("llama-3.1-8b", 32, 4096, 32, 8, 14336,
                           128256),
    "llama-3.1-70b": _dense("llama-3.1-70b", 80, 8192, 64, 8, 28672,
                            128256),
}

PAPER_PROMPT_MEAN = 1200        # §3.1: s_mean ~ 1200
PAPER_OUTPUT_MEAN = 80          # §2: outputs 10-300, chat-like


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def save_results(bench: str, rows: List[Dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, bench + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def timeit(fn: Callable, n: int = 3) -> float:
    """Median wall-time of fn() in microseconds."""
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
