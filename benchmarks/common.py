"""Shared benchmark infrastructure.

Defines the paper's model zoo (§2: Qwen-2.5 0.5–14B, Mistral-7B,
LLaMA-3.1-8B/70B) as ModelConfigs, plus CSV/reporting helpers. Energy
numbers come from the phase-aware analytic model on H100 constants
(the paper's measurement platform); latency micro-measurements for the
real-compute benches run reduced models on CPU.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List

# the paper's §2 model selection (single source of truth in src)
from repro.configs.paper_zoo import PAPER_MODELS  # noqa: F401

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "bench")

PAPER_PROMPT_MEAN = 1200        # §3.1: s_mean ~ 1200
PAPER_OUTPUT_MEAN = 80          # §2: outputs 10-300, chat-like


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def save_results(bench: str, rows: List[Dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, bench + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def paper_requests(n: int, arrivals, seed: int = 0,
                   prompt_range=None) -> list:
    """Serving requests sampled from the paper's §2/§3.1 workload
    distribution (shared by the serving and cluster benchmarks)."""
    from repro.serving import Request
    from repro.training.data import RequestDistribution
    kw = {"seed": seed}
    if prompt_range is not None:
        kw["prompt_range"] = prompt_range
    dist = RequestDistribution(**kw)
    out = []
    for i in range(n):
        s = dist.sample()
        out.append(Request(req_id=i, prompt=None, prompt_len=s.prompt_len,
                           max_new_tokens=s.output_len,
                           arrival_time=arrivals[i]))
    return out


def timeit(fn: Callable, n: int = 3) -> float:
    """Median wall-time of fn() in microseconds."""
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
