"""Roofline report: renders the 40-pair baseline table from the
dry-run JSON artifacts (deliverable g)."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row, save_results

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_results(mesh: str = "pod16x16") -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("ok") \
                and r.get("fmt") == "bfloat16" and not r.get("kv_quant"):
            out.append(r)
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    table = []
    for r in load_results():
        rf = r["roofline"]
        step = max(rf["t_compute_s"], rf["t_memory_s"]) \
            + rf["t_collective_s"]
        table.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_s": rf["t_compute_s"],
            "t_memory_s": rf["t_memory_s"],
            "t_collective_s": rf["t_collective_s"],
            "bottleneck": rf["bottleneck"],
            "useful_flop_ratio": rf["useful_flop_ratio"],
            "roofline_fraction": rf["roofline_fraction"],
            "step_s": step,
        })
        rows.append(Row(
            name=f"roofline/{r['arch']}/{r['shape']}",
            us_per_call=step * 1e6,
            derived=(f"bound={rf['bottleneck']} "
                     f"frac={rf['roofline_fraction']:.3f} "
                     f"useful={rf['useful_flop_ratio']:.2f}")))
    if not table:
        rows.append(Row("roofline/missing", 0.0,
                        "run: python -m repro.launch.dryrun first"))
    save_results("roofline", table)
    return rows
