"""Control suite: closed-loop DVFS against time-varying load.

The paper's serving-strategy result, actuated from *inside* the loop:
a static DVFS point must be provisioned for the crest of the day and
wastes energy all night, while a controller that observes queue depth
and arrival rate can ride the load curve. Three arrival shapes over
the single-replica serve engine:

* **Diurnal** (sine day, 0.85 amplitude): the headline frontier claim.
  :class:`repro.control.MPCController` plans DVFS over the same
  analytic substrate the simulator bills with; it must land a
  (Wh/request, p99) point that *dominates* the static grid — every
  static frequency with p99 within 1.05x of the MPC's costs >=1.2x
  the energy. A reactive threshold controller rides along as the
  classical baseline.
* **Bursty** (batch-sized bursts, idle gaps): SLO tightness is a
  priced knob — tightening ``slo_p99_s`` from 6 s to 2 s must buy
  latency (>=1.2x lower p99) and cost energy (the tight controller
  spends >=1.1x the Wh/request), monotone in the direction the
  paper's serving-strategy section predicts.
* **Shaped** (deterministic low->step->low profile): the controller
  tracks a step change it has never seen; same frontier construction
  as diurnal with a stronger threshold (the step plateau is exactly
  where static provisioning is worst).

Environment knobs (CI smoke / quick mode):
* ``REPRO_CONTROL_NREQ`` — requests in the diurnal day (default 4200;
  ``--quick`` sets 1400). The other scenarios scale proportionally,
  holding arrival *rates* fixed so the control dynamics are preserved.
"""
from __future__ import annotations

import os
from typing import List, Mapping

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, RunResult, sweep

#: diurnal-day request count; the simulated day shrinks with it so the
#: offered rates (and hence the controller's operating regime) hold
N_DIURNAL = int(os.environ.get("REPRO_CONTROL_NREQ", "4200"))
_SCALE = N_DIURNAL / 4200.0
RATE_PER_S = 7.0
PERIOD_S = N_DIURNAL / RATE_PER_S

#: static DVFS grid the controller is judged against
FREQ_POINTS = (0.4, 0.5, 0.6, 0.7, 0.85, 1.0)

#: the controller may also downclock *below* the static grid: a fixed
#: 0.25 point can never serve the crest (capacity < peak rate), but a
#: controller can visit it every trough — that asymmetry is the win
MPC_PARAMS = {"slo_p99_s": 1.3, "slo_weight": 150.0,
              "freq_grid": (0.25,) + FREQ_POINTS}
CONTROL_INTERVAL_S = 2.0

_WORKLOAD = dict(model="llama-3.1-8b", max_batch=32,
                 prompt_range=(200, 4000), output_range=(10, 300))

DIURNAL_BASE = ExperimentSpec(
    n_requests=N_DIURNAL, arrival="diurnal",
    arrival_params={"base_rate_per_s": RATE_PER_S, "period_s": PERIOD_S,
                    "amp_frac": 0.85},
    **_WORKLOAD)

BURST_BASE = ExperimentSpec(
    n_requests=max(int(1920 * _SCALE), 192), arrival="burst",
    arrival_params={"burst_size": 96, "burst_gap_s": 15.0},
    controller="mpc", control_interval_s=CONTROL_INTERVAL_S,
    **_WORKLOAD)


def _shaped_times(n: int, rates, span_s: float):
    """Deterministic piecewise-constant arrival profile: ``rates``
    split ``span_s`` into equal segments, requests arrive evenly
    within each — a load *shape* with no sampling noise."""
    times, t, seg = [], 0.0, len(rates)
    while len(times) < n:
        seg_i = min(int(t / (span_s / seg)), seg - 1)
        t += 1.0 / rates[seg_i]
        times.append(round(t, 6))
    return tuple(times[:n])


N_SHAPED = max(int(2400 * _SCALE), 240)
SHAPED_BASE = ExperimentSpec(
    n_requests=N_SHAPED, arrival="explicit",
    arrival_params={"times": _shaped_times(N_SHAPED, (3.0, 12.0, 3.0),
                                           400.0 * _SCALE)},
    **_WORKLOAD)


def _static_options() -> List[Option]:
    return [Option(f"static_f{f:.2f}", freq_scale=f)
            for f in FREQ_POINTS]


def _mpc_option() -> Option:
    return Option("mpc", controller="mpc", controller_params=MPC_PARAMS,
                  control_interval_s=CONTROL_INTERVAL_S)


def _frontier_ratio(tag: str):
    """min Wh/request over static points at matched (<=1.05x) p99,
    divided by the MPC's Wh/request. Infinity when no static point
    matches the MPC's latency at all (total domination)."""
    def fn(results: Mapping[str, RunResult]) -> float:
        mpc = results[f"{tag}/mpc"]
        matched = [r for k, r in results.items()
                   if k.startswith(f"{tag}/static_")
                   and r.latency_p99_s <= 1.05 * mpc.latency_p99_s]
        if not matched:
            return float("inf")
        return (min(r.mean_energy_wh for r in matched)
                / mpc.mean_energy_wh)
    return fn


CLAIMS = (
    Claim("mpc_beats_static_frontier_diurnal",
          value_fn=_frontier_ratio("diurnal"), op=">=", threshold=1.2),
    Claim("mpc_beats_static_frontier_shaped",
          value_fn=_frontier_ratio("shaped"), op=">=", threshold=1.3),
    Claim("slo_tightness_costs_energy", metric="mean_energy_wh",
          ratio_of=("burst/slo_tight", "burst/slo_loose"),
          op=">=", threshold=1.1),
    Claim("slo_tightness_buys_latency", metric="latency_p99_s",
          ratio_of=("burst/slo_loose", "burst/slo_tight"),
          op=">=", threshold=1.2),
    Claim("mpc_completes_every_request", metric="n_shed",
          value_of="*/mpc", agg="max", op="<=", threshold=0.0),
)


def run() -> List[Row]:
    res = sweep(DIURNAL_BASE, {
        "operating": _static_options() + [
            _mpc_option(),
            Option("reactive", controller="reactive",
                   control_interval_s=CONTROL_INTERVAL_S),
        ],
    }, tag="diurnal")
    res = res.merge(sweep(BURST_BASE, {
        "slo": [Option("slo_tight",
                       controller_params={**MPC_PARAMS,
                                          "slo_p99_s": 2.0}),
                Option("slo_loose",
                       controller_params={**MPC_PARAMS,
                                          "slo_p99_s": 6.0})],
    }, tag="burst"))
    res = res.merge(sweep(SHAPED_BASE, {
        "operating": _static_options()[1::2] + [_mpc_option()],
    }, tag="shaped"))
    res.check(CLAIMS)

    rows = [Row(name=f"control/{label}",
                us_per_call=r.latency_p50_s * 1e6,
                derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                         f"p99={r.latency_p99_s:.2f}s"
                         + (f" meanf={r.mean_freq_scale:.3f}"
                            f" acts={r.n_control_actions}"
                            if r.mean_freq_scale is not None else "")),
                spec_hash=r.spec_hash)
            for label, r in res.results.items()]
    rows += claim_rows(res.claims)
    save_sweep("control", res)
    return rows
