"""Batch-formation sweep (policy x arrival x output length) over the
:class:`repro.ExperimentSpec` ``batch_policy=`` / ``policy_params=`` /
``disaggregate=`` axes.

Length-aware extension of Fig 2: prefill is compute-bound and pays for
every padded token, so *which* requests are batched together decides
where a configuration lands on the Wh/request x p99 frontier.  Under a
loaded Poisson queue with the paper's log-uniform prompt mix:

* ``length_sorted`` admits minimal-padding windows of similar-length
  requests — it cuts padded prefill tokens by multiples versus the
  bucket-grouped FIFO baseline, and that surplus compute was pure
  energy: strictly lower Wh/request at matched-or-better p99 (the
  headline claim of this suite),
* ``chunked_prefill`` splits long prompts into exact unpadded chunks
  interleaved with decode — on a long-prompt mix it removes padding
  entirely and beats slot-count on both Wh and p99,
* ``token_budget`` caps committed tokens instead of slots — in this
  simulator over-admission carries no OOM penalty, so the honest claim
  is bounded commitment at energy parity and no-worse tail latency,
* ``disaggregate=1`` (2 replicas) dedicates one replica to prefill and
  one to decode with explicit KV-handoff billing (bytes x pJ/byte +
  link latency) — consolidating decode into one always-warm replica
  beats the mixed 2-replica fleet on Wh/request, and every request's
  handoff is accounted.

Environment knobs (CI smoke / quick mode):
* ``REPRO_FORMATION_NREQ`` — requests per scenario (default 160).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_REQ = int(os.environ.get("REPRO_FORMATION_NREQ", "160"))
#: long-prompt scenario size (chunked-prefill rows are per-request
#: expensive: tens of chunks each)
N_LONG = max(N_REQ * 3 // 5, 16)

BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", max_batch=16,
                      n_requests=N_REQ,
                      prompt_range=(200, 4000), output_range=(10, 300),
                      arrival="poisson",
                      arrival_params={"rate_per_s": 8.0})

#: the paper-mix policy axis; slot_count carries an explicit (default-
#: valued) policy_params so its row records formation telemetry while
#: remaining bit-identical to the plain default engine
POLICY_AXIS = [
    Option("slot_count", batch_policy="slot_count",
           policy_params={"bucket_prefill": True}),
    Option("length_sorted", batch_policy="length_sorted"),
    Option("token_budget", batch_policy="token_budget",
           policy_params={"token_budget": 24000}),
    Option("chunked", batch_policy="chunked_prefill",
           policy_params={"chunk_tokens": 512}),
]

CLAIMS = (
    # headline: length-aware formation strictly saves energy at
    # matched-or-better tail latency (acceptance pair)
    Claim("length_sorted_saves_energy",
          ratio_of=("slot_count/paper_mix", "length_sorted/paper_mix"),
          op=">", threshold=1.02),
    Claim("length_sorted_p99_no_worse",
          ratio_of=("slot_count/paper_mix", "length_sorted/paper_mix"),
          metric="latency_p99_s", threshold=1.0),
    Claim("length_sorted_cuts_padding",
          ratio_of=("slot_count/paper_mix", "length_sorted/paper_mix"),
          metric="prefill_padding_fraction", op=">", threshold=3.0),
    # token budget: bounded commitment is free — energy parity, tail
    # no worse than slot-count under the same load
    Claim("token_budget_energy_parity",
          ratio_of=("token_budget/paper_mix", "slot_count/paper_mix"),
          op="<=", threshold=1.005),
    Claim("token_budget_p99_no_worse",
          ratio_of=("slot_count/paper_mix", "token_budget/paper_mix"),
          metric="latency_p99_s", threshold=1.0),
    # chunked prefill on the long-prompt mix: exact chunks remove
    # padding, and interleaving keeps decode moving
    Claim("chunked_saves_energy_long_prompts",
          ratio_of=("long/slot_count", "long/chunked"),
          op=">", threshold=1.03),
    Claim("chunked_p99_better_long_prompts",
          ratio_of=("long/slot_count", "long/chunked"),
          metric="latency_p99_s", op=">", threshold=1.0),
    # disaggregation: consolidated decode beats the mixed 2-replica
    # fleet, and every request's KV handoff is billed
    Claim("disagg_beats_mixed_fleet",
          ratio_of=("fleet/mixed", "fleet/disagg"),
          op=">", threshold=1.0),
    Claim("disagg_bills_every_handoff",
          value_of="fleet/disagg", metric="n_handoffs",
          op=">=", threshold=N_REQ),
)


def run() -> List[Row]:
    res = sweep(BASE, {
        "policy": POLICY_AXIS,
        "scenario": [Option("paper_mix")],
    })

    # long-prompt mix: where monolithic prefill stalls live decodes
    long_mix = BASE.derive(n_requests=N_LONG,
                           prompt_range=(2000, 16000),
                           output_range=(50, 300),
                           arrival_params={"rate_per_s": 2.0})
    res = res.merge(sweep(long_mix, {
        "policy": [POLICY_AXIS[0], POLICY_AXIS[3]],
    }, tag="long"))

    # 2-replica fleet: mixed replicas vs disaggregated prefill/decode
    fleet = BASE.derive(replicas=2)
    res = res.merge(sweep(fleet, {
        "split": [Option("mixed"),
                  Option("disagg", disaggregate=1)],
    }, tag="fleet"))
    res.check(CLAIMS)

    rows = []
    for label, r in res.results.items():
        extra = ""
        if r.prefill_padding_fraction is not None:
            extra = f" pad={r.prefill_padding_fraction:.3f}"
        if r.n_handoffs:
            extra += (f" handoffs={r.n_handoffs} "
                      f"handoffJ={r.handoff_energy_j:.1f}")
        rows.append(Row(
            name=f"formation/{label}",
            us_per_call=r.latency_p50_s * 1e6,
            derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                     f"p99={r.latency_p99_s:.2f}s "
                     f"ttft_p99={r.ttft_p99_s:.2f}s"
                     f"{extra}"),
            spec_hash=r.spec_hash))
    rows += claim_rows(res.claims)
    save_sweep("formation", res)
    return rows
