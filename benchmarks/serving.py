"""Paper Fig. 3a/3c (serving config + arrival shaping) and Fig. 3b
(70B scaling), via the discrete-event serving engine.

Claims validated:
* naive (sequential transformers, bf16) ~= 0.12 Wh/request (paper 3a),
* TGI-style continuous batching >= 10x better than naive,
* best FIXED inter-arrival spacing -> >= 50x vs naive (paper: up to
  100x; the exact optimal interval depends on per-step service time —
  we sweep intervals and report the best, see EXPERIMENTS.md),
* fixed spacing >= uniform-random spacing at equal mean rate,
* LLaMA-70B on 4 chips with continuous batching beats the naive 8B
  baseline per request (paper 3b).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (PAPER_MODELS, Row, paper_requests,
                               save_results)
from repro.serving import (ServeEngine, fixed_arrivals,
                           uniform_random_arrivals)

N_REQ = 400
INTERVALS_MS = (10, 20, 50, 100, 300, 500)

_requests = paper_requests


def run() -> List[Row]:
    cfg8 = PAPER_MODELS["llama-3.1-8b"]
    cfg70 = PAPER_MODELS["llama-3.1-70b"]
    rows: List[Row] = []
    results = {}

    def record(name, rep):
        results[name] = rep.summary()
        rows.append(Row(
            name=f"fig3/{name}",
            us_per_call=rep.mean_latency_s * 1e6,
            derived=(f"Wh/req={rep.mean_energy_per_request_wh:.5f} "
                     f"batch={rep.mean_batch:.1f} "
                     f"idle={rep.summary()['idle_fraction']:.2f}")))
        return rep

    # naive: sequential transformers (bf16), back-to-back requests
    naive = record("naive_sequential_bf16", ServeEngine(
        cfg8, fmt="bfloat16", mode="sequential").run(
        _requests(N_REQ, [0.0] * N_REQ)))

    # TGI-like burst
    tgi_burst = record("tgi_burst", ServeEngine(
        cfg8, fmt="bfloat16", mode="continuous", max_batch=64).run(
        _requests(N_REQ, [0.0] * N_REQ)))

    # arrival shaping sweep: fixed vs random at each interval (Fig 3c)
    best_fixed = None
    for ms in INTERVALS_MS:
        rep_f = record(f"fixed_{ms}ms", ServeEngine(
            cfg8, fmt="bfloat16", mode="continuous", max_batch=64).run(
            _requests(N_REQ, fixed_arrivals(N_REQ, ms / 1e3))))
        record(f"random_{ms}ms", ServeEngine(
            cfg8, fmt="bfloat16", mode="continuous", max_batch=64).run(
            _requests(N_REQ, uniform_random_arrivals(
                N_REQ, 0.0, 2 * ms / 1e3))))
        if (best_fixed is None
                or rep_f.mean_energy_per_request_wh
                < best_fixed.mean_energy_per_request_wh):
            best_fixed = rep_f

    # Fig 3b: 70B on 4 chips
    rep70 = record("llama70b_tgi_burst_4chip", ServeEngine(
        cfg70, fmt="bfloat16", mode="continuous", max_batch=64,
        n_chips=4).run(_requests(N_REQ, [0.0] * N_REQ)))

    # short-prompt scenario: the paper's 100x headline is only
    # physically reachable when the per-request prefill compute floor
    # (2*N*prompt at 700 W) is small vs the naive decode cost — see
    # EXPERIMENTS.md §Validation for the floor analysis. prompts 200-600
    # put the workload in that regime.
    def _short(n, arrivals, seed=0):
        return paper_requests(n, arrivals, seed=seed,
                              prompt_range=(200, 600))

    naive_s = record("short/naive_sequential_bf16", ServeEngine(
        cfg8, fmt="bfloat16", mode="sequential").run(
        _short(N_REQ, [0.0] * N_REQ)))
    best_s = None
    for ms in (10, 20, 50):
        rep = record(f"short/fixed_{ms}ms", ServeEngine(
            cfg8, fmt="bfloat16", mode="continuous", max_batch=64).run(
            _short(N_REQ, fixed_arrivals(N_REQ, ms / 1e3))))
        if (best_s is None or rep.mean_energy_per_request_wh
                < best_s.mean_energy_per_request_wh):
            best_s = rep

    naive_wh = naive.mean_energy_per_request_wh
    short_ratio = (naive_s.mean_energy_per_request_wh
                   / best_s.mean_energy_per_request_wh)
    checks = {
        "naive_near_paper_0.12wh": (naive_wh, 0.04 < naive_wh < 0.4),
        "tgi_ge_10x_better": (naive_wh / tgi_burst
                              .mean_energy_per_request_wh,
                              naive_wh / tgi_burst
                              .mean_energy_per_request_wh >= 10),
        # paper: up to 100x. With the §2 workload (prompts 200-4000) the
        # prefill compute floor caps the ratio near ~30x; we assert the
        # honest >=15x here and >=40x in the short-prompt regime below.
        "best_fixed_ge_15x_paper_workload": (
            naive_wh / best_fixed.mean_energy_per_request_wh,
            naive_wh / best_fixed.mean_energy_per_request_wh >= 15),
        "best_fixed_ge_40x_short_prompts": (short_ratio,
                                            short_ratio >= 40),
        "fixed_beats_random_at_best": (
            results["random_10ms"]["mean_energy_wh"]
            / results["fixed_10ms"]["mean_energy_wh"],
            results["fixed_10ms"]["mean_energy_wh"]
            <= results["random_10ms"]["mean_energy_wh"] * 1.05),
        "70b_tgi_beats_naive_8b": (
            naive_wh / rep70.mean_energy_per_request_wh,
            rep70.mean_energy_per_request_wh < naive_wh),
    }
    for k, (v, ok) in checks.items():
        rows.append(Row(name=f"claim/{k}", us_per_call=0.0,
                        derived=f"value={v:.2f} pass={ok}"))
    save_results("serving", [{"results": results,
                              "checks": {k: [float(v), bool(ok)]
                                         for k, (v, ok)
                                         in checks.items()}}])
    return rows
