"""Paper Fig. 3a/3c (serving config + arrival shaping) and Fig. 3b
(70B scaling) as a declarative sweep over :class:`repro.ExperimentSpec`.

Claims validated (same rows as ever, now produced by `repro.Claim`
objects over the sweep instead of hand-assembled checks):
* naive (sequential transformers, bf16) ~= 0.12 Wh/request (paper 3a),
* TGI-style continuous batching >= 10x better than naive,
* best FIXED inter-arrival spacing -> >= 15x vs naive on the §2
  workload and >= 40x in the short-prompt regime (paper: up to 100x;
  see EXPERIMENTS.md for the prefill-floor analysis),
* fixed spacing >= uniform-random spacing at equal mean rate,
* LLaMA-70B on 4 chips with continuous batching beats the naive 8B
  baseline per request (paper 3b).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_REQ = 400
INTERVALS_MS = (10, 20, 50, 100, 300, 500)

BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", max_batch=64, n_requests=N_REQ)

def _fixed(ms: int, prefix: str = "") -> Option:
    return Option(f"{prefix}fixed_{ms}ms", arrival="fixed",
                  arrival_params={"interval_s": ms / 1e3})


def _random(ms: int) -> Option:
    return Option(f"random_{ms}ms", arrival="uniform",
                  arrival_params={"low_s": 0.0, "high_s": 2 * ms / 1e3})


CLAIMS = (
    Claim("naive_near_paper_0.12wh", value_of="naive_sequential_bf16",
          op="range", threshold=(0.04, 0.4)),
    Claim("tgi_ge_10x_better",
          ratio_of=("naive_sequential_bf16", "tgi_burst"),
          threshold=10.0),
    # paper: up to 100x. With the §2 workload (prompts 200-4000) the
    # prefill compute floor caps the ratio near ~30x; the >=40x short-
    # prompt claim below covers the regime where the headline lives.
    Claim("best_fixed_ge_15x_paper_workload",
          ratio_of=("naive_sequential_bf16", "fixed_*ms"),
          agg_den="min", threshold=15.0),
    Claim("best_fixed_ge_40x_short_prompts",
          ratio_of=("short/naive_sequential_bf16", "short/fixed_*ms"),
          agg_den="min", threshold=40.0),
    Claim("fixed_beats_random_at_best",
          ratio_of=("random_10ms", "fixed_10ms"),
          threshold=1.0 / 1.05),
    Claim("70b_tgi_beats_naive_8b",
          ratio_of=("naive_sequential_bf16", "llama70b_tgi_burst_4chip"),
          op=">", threshold=1.0),
)


def run() -> List[Row]:
    res = sweep(BASE, {"scenario": [
        # Fig 3a: naive sequential vs TGI-like burst
        Option("naive_sequential_bf16", mode="sequential"),
        Option("tgi_burst"),
        # Fig 3c: arrival-shaping sweep, fixed vs random per interval
        *[_fixed(ms) for ms in INTERVALS_MS],
        *[_random(ms) for ms in INTERVALS_MS],
        # Fig 3b: 70B on 4 chips
        Option("llama70b_tgi_burst_4chip", model="llama-3.1-70b",
               n_chips=4),
        # short-prompt regime (prompts 200-600): where the paper's 100x
        # headline is physically reachable — see EXPERIMENTS.md
        Option("short/naive_sequential_bf16", mode="sequential",
               prompt_range=(200, 600)),
        *[Option(f"short/fixed_{ms}ms", arrival="fixed",
                 arrival_params={"interval_s": ms / 1e3},
                 prompt_range=(200, 600)) for ms in (10, 20, 50)],
    ]}, claims=CLAIMS)

    rows = [Row(name=f"fig3/{label}",
                us_per_call=r.mean_latency_s * 1e6,
                derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                         f"batch={r.mean_batch:.1f} "
                         f"idle={r.idle_fraction:.2f}"),
                spec_hash=r.spec_hash)
            for label, r in res.results.items()]
    rows += claim_rows(res.claims)
    save_sweep("serving", res)
    return rows
