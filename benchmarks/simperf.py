"""Simulator performance suite: how fast is the simulator *itself*?

The paper's system-level sweeps (and the ROADMAP's "heavy traffic from
millions of users") need the discrete-event core to push millions of
simulated tokens per host-second. This suite measures exactly that:

* ``single_step`` vs ``macro_step`` wall time on a long-decode serving
  workload (identical requests, identical simulated results — the
  macro-stepped engine is bit-identical by construction, and the suite
  re-asserts it);
* simulated-tokens/sec and requests/sec of the macro-stepped engine at
  10k / 100k / 1M-request scale (single-stepping the larger scales is
  exactly the infeasibility this PR removes, so only the smallest scale
  carries a baseline measurement);
* fleet-scaling rows (16 / 64 / 256 replicas): the vectorized
  :class:`~repro.fleet.FleetEngine` against the Python-loop
  ``ClusterEngine`` on the same engines and requests, with a >=5x
  wall-clock gate at 64 replicas and a field-for-field parity check.

Claim-style guards (same ``claim/...`` row schema run.py exits on):
``macro_speedup_ge_5x`` is the CI gate; the full (non-quick) run also
checks the >=10x long-decode target and that the 1M-request scale
actually completes. ``REPRO_SIMPERF_QUICK=1`` (set by ``--quick``)
shrinks everything to CI-smoke size.
"""
from __future__ import annotations

import os
import time
from typing import List

from benchmarks.common import Row, save_results
from repro.configs.paper_zoo import PAPER_MODELS
from repro.fleet import FleetEngine
from repro.serving.arrival import burst_arrivals, paper_requests
from repro.serving.cluster import ClusterEngine
from repro.serving.engine import ServeEngine
from repro.batching.policy import SlotCountPolicy

CFG = PAPER_MODELS["llama-3.1-8b"]

#: long-decode serving workload: deep bursts keep the decode batch full
#: for hundreds of uninterrupted steps — the regime the paper's Fig 2
#: batching result lives in, and the best case for event horizons
LONG_DECODE = dict(prompt_range=(200, 2000), output_range=(256, 1024))
#: chat-like workload for the scaling rows (the §2 distribution)
CHAT = dict(prompt_range=(200, 4000), output_range=(10, 300))


def _quick() -> bool:
    return os.environ.get("REPRO_SIMPERF_QUICK", "") == "1"


def _requests(n: int, shape: dict, burst: int = 64,
              gap_s: float = 30.0) -> list:
    return paper_requests(n, burst_arrivals(n, burst, gap_s), seed=0,
                          **shape)


def _timed_run(n: int, shape: dict, *, macro: bool,
               max_batch: int = 32) -> dict:
    eng = ServeEngine(CFG, macro_step=macro, batch_policy=SlotCountPolicy(max_batch=max_batch))
    reqs = _requests(n, shape)
    t0 = time.perf_counter()
    rep = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(r.tokens_generated for r in rep.requests)
    return {"wall_s": dt, "tokens": toks, "n": n,
            "toks_per_s": toks / dt, "req_per_s": n / dt,
            "steps": rep.n_decode_steps,
            "energy_j": rep.total_energy_j,
            "wall_time_s": rep.wall_time_s}


def _claim_row(name: str, value: float, passed: bool) -> Row:
    return Row(name=f"claim/{name}", us_per_call=0.0,
               derived=f"value={value:.2f} pass={passed}")


#: batch-coherent fleet workload: fleet-width waves of identically
#: shaped requests, so whole batches admit and complete together — the
#: cost sits exactly where the two cluster loops differ (per-arrival
#: replica scanning vs vectorized state)
FLEET_SHAPE = dict(prompt_range=(400, 400), output_range=(8, 8))


def _fleet_replicas(R: int, mb: int) -> list:
    return [ServeEngine(CFG, batch_policy=SlotCountPolicy(max_batch=mb))
            for _ in range(R)]


def _fleet_best_wall(make_engine, R: int, mb: int, mult: int,
                     reps: int) -> tuple:
    """Best-of-``reps`` wall time (first-run allocator warm-up and
    host noise would otherwise dominate a single sample)."""
    n = R * mb * mult
    best, report = float("inf"), None
    for _ in range(reps):
        eng = make_engine(_fleet_replicas(R, mb))
        reqs = paper_requests(n, burst_arrivals(n, R * mb, 8.0),
                              seed=0, **FLEET_SHAPE)
        t0 = time.perf_counter()
        report = eng.run(reqs)
        best = min(best, time.perf_counter() - t0)
    return best, report


def run() -> List[Row]:
    quick = _quick()
    rows: List[Row] = []
    dump: List[dict] = []

    # -- 1. single-step vs macro-step on the long-decode workload -------
    n_base = 96 if quick else 256
    single = _timed_run(n_base, LONG_DECODE, macro=False)
    macro = _timed_run(n_base, LONG_DECODE, macro=True)
    speedup = single["wall_s"] / macro["wall_s"]
    parity = (single["energy_j"] == macro["energy_j"]
              and single["wall_time_s"] == macro["wall_time_s"]
              and single["steps"] == macro["steps"])
    rows += [
        Row("simperf/single_step_toks_per_s", single["wall_s"] * 1e6,
            f"{single['toks_per_s']:.3g} sim-tok/s "
            f"({single['steps']} steps)"),
        Row("simperf/macro_step_toks_per_s", macro["wall_s"] * 1e6,
            f"{macro['toks_per_s']:.3g} sim-tok/s "
            f"({macro['steps']} steps)"),
        Row("simperf/macro_speedup", 0.0,
            f"{speedup:.1f}x wall-clock on long-decode"),
    ]
    dump += [{"scale": n_base, "mode": m, **r}
             for m, r in (("single", single), ("macro", macro))]
    # the CI gate (quick workload included) + the full-mode target
    rows.append(_claim_row("macro_speedup_ge_5x", speedup,
                           speedup >= 5.0))
    if not quick:
        rows.append(_claim_row("macro_speedup_ge_10x_long_decode",
                               speedup, speedup >= 10.0))
    rows.append(_claim_row("macro_bit_parity", float(parity), parity))

    # -- 1b. control plumbing is free when off ---------------------------
    # the controller hooks live on the hot event loop; a run with no
    # controller must take the legacy code path — bit-identical
    # results and no measurable wall-clock cost (best-of-3 vs host
    # noise). Guards the PR-9 "zero cost when off" contract.
    def _best_wall(**kw):
        best, rep = float("inf"), None
        for _ in range(3):
            eng = ServeEngine(CFG, macro_step=True,
                              batch_policy=SlotCountPolicy(max_batch=32))
            reqs = _requests(n_base, LONG_DECODE)
            t0 = time.perf_counter()
            rep = eng.run(reqs, **kw)
            best = min(best, time.perf_counter() - t0)
        return best, rep
    t_legacy, rep_legacy = _best_wall()
    t_off, rep_off = _best_wall(controller=None)
    off_parity = (rep_legacy.total_energy_j == rep_off.total_energy_j
                  and rep_legacy.wall_time_s == rep_off.wall_time_s
                  and rep_legacy.n_decode_steps == rep_off.n_decode_steps)
    off_ratio = t_off / t_legacy
    rows.append(Row("simperf/controller_off_wall", t_off * 1e6,
                    f"{off_ratio:.2f}x legacy wall (off vs never)"))
    rows.append(_claim_row("controller_off_bit_parity",
                           float(off_parity), off_parity))
    rows.append(_claim_row("controller_off_zero_overhead", off_ratio,
                           off_ratio <= 1.15))
    dump.append({"controller_off_ratio": off_ratio,
                 "parity": off_parity})

    # -- 2. macro-stepped scaling: 10k / 100k / 1M requests --------------
    scales = [10_000] if quick else [10_000, 100_000, 1_000_000]
    for n in scales:
        r = _timed_run(n, CHAT, macro=True, max_batch=64)
        rows.append(Row(
            f"simperf/scale_{n//1000}k", r["wall_s"] * 1e6,
            f"{r['toks_per_s']:.3g} sim-tok/s "
            f"{r['req_per_s']:.3g} req/s {r['wall_s']:.1f}s host"))
        dump.append({"scale": n, "mode": "macro", **r})
        if n == 1_000_000:
            rows.append(_claim_row("sim_1m_requests_feasible",
                                   r["wall_s"],
                                   r["wall_s"] < 900.0))

    # -- 3. fleet vectorization: FleetEngine vs the ClusterEngine loop ---
    # the legacy loop rescans every replica per arrival (O(R) per
    # event); the vectorized fleet keeps struct-of-arrays state. Same
    # engines, same requests, asserted field-for-field identical.
    mult = 4 if quick else 6
    parity_all = True
    for R in (16, 64) if quick else (16, 64, 256):
        mb, m = (32, 2) if R == 256 else (64, mult)
        tf, rf = _fleet_best_wall(
            lambda e: FleetEngine(e, policy="least_loaded"),
            R, mb, m, reps=3)
        tc, rc = _fleet_best_wall(
            lambda e: ClusterEngine(e, policy="least_loaded"),
            R, mb, m, reps=3)
        ratio = tc / tf
        parity = (rf.total_energy_j == rc.total_energy_j
                  and rf.wall_time_s == rc.wall_time_s)
        parity_all &= parity
        n = R * mb * m
        rows.append(Row(
            f"simperf/fleet_scaling_r{R}", tf * 1e6,
            f"{ratio:.1f}x vs loop ({n} req: fleet {tf:.2f}s, "
            f"loop {tc:.2f}s)"))
        dump.append({"fleet_replicas": R, "n": n, "fleet_wall_s": tf,
                     "loop_wall_s": tc, "ratio": ratio,
                     "parity": parity})
        if R == 64:
            rows.append(_claim_row("fleet_vector_speedup_ge_5x_r64",
                                   ratio, ratio >= 5.0))
    rows.append(_claim_row("fleet_vector_parity", float(parity_all),
                           parity_all))

    save_results("simperf", [{"results": dump}])
    return rows
