"""Fleet suite: geo-routing, autoscaling, and planet-scale throughput.

The paper's orchestration result — *when and where* a request runs
moves its energy more than the arithmetic does — at its largest scale.
Three scenarios over the vectorized :class:`repro.fleet.FleetEngine`:

* **Geo-routing** (2 regions, carbon/price sinusoids in anti-phase):
  carbon-aware routing chases the cleaner grid around the planet.
  Claims: >=1.3x lower gCO2/request than gated round-robin at matched
  (<=1.1x) client p99, and the price-aware variant cuts $/request.
* **Autoscaling** (diurnal day, 8-replica fleet): the target-
  utilization policy drains the fleet off-peak and spins it back up
  for the crest, beating static provisioning on Wh/request while the
  transition energy stays on the ledger.
* **Scale** (256 replicas, 4 regions, ``REPRO_FLEET_NREQ`` requests —
  10M by default): one declarative ``sweep()`` point must complete in
  minutes of host time, the ROADMAP's "planet-scale sweeps are cheap"
  bar.

Environment knobs (CI smoke / quick mode):
* ``REPRO_FLEET_NREQ`` — requests in the scale scenario (default 10M;
  ``--quick`` sets 262144 and relaxes the wall-clock bound to 120 s).
"""
from __future__ import annotations

import os
import time
from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep
from repro.fleet import sinusoid_region

#: scale-scenario request count (the claim bound adapts: 900 s host
#: wall at >=1M requests, 120 s below — CI smoke uses 262144)
N_SCALE = int(os.environ.get("REPRO_FLEET_NREQ", "10000000"))

#: compressed simulated "day" — the carbon/price sinusoids and the
#: diurnal arrival wave share this period, so two anti-phase regions
#: really are clean/dirty in opposition within the run's window
PERIOD_S = 1200.0
RATE_PER_S = 8.0
N_DAY = int(RATE_PER_S * PERIOD_S)

GEO_REGIONS = [
    sinusoid_region("us-west", carbon_mean=350.0, carbon_amp=300.0,
                    phase_h=0.0, period_s=PERIOD_S, replicas=2,
                    price_mean=0.12, price_amp=0.05),
    sinusoid_region("eu-central", carbon_mean=350.0, carbon_amp=300.0,
                    phase_h=PERIOD_S / 7200.0,      # exact anti-phase
                    period_s=PERIOD_S, replicas=2,
                    price_mean=0.10, price_amp=0.05),
]

GEO_BASE = ExperimentSpec(
    model="llama-3.1-8b", mode="continuous", max_batch=16,
    replicas=4, n_requests=N_DAY, regions=GEO_REGIONS,
    arrival="diurnal",
    arrival_params={"base_rate_per_s": RATE_PER_S, "period_s": PERIOD_S,
                    "amp_frac": 0.6})

# gated baselines: every router may power-gate idle replicas, so the
# carbon win below is *routing* (following the clean grid), not the
# idle-power discount
GEO_POLICIES = ("round_robin_gated", "least_loaded_gated",
                "carbon_aware_gated", "price_aware_gated")

AUTO_BASE = ExperimentSpec(
    model="llama-3.1-8b", mode="continuous", max_batch=8,
    replicas=8, n_requests=N_DAY, fleet="vector",
    arrival="diurnal",
    arrival_params={"base_rate_per_s": RATE_PER_S, "period_s": PERIOD_S,
                    "amp_frac": 0.9})

CLAIMS = (
    Claim("carbon_routing_cuts_gco2", metric="gco2_per_request_g",
          ratio_of=("geo/round_robin_gated", "geo/carbon_aware_gated"),
          op=">=", threshold=1.3),
    Claim("carbon_routing_p99_matched", metric="client_latency_p99_s",
          ratio_of=("geo/carbon_aware_gated", "geo/round_robin_gated"),
          op="<=", threshold=1.1),
    Claim("price_routing_cuts_usd", metric="usd_per_request",
          ratio_of=("geo/round_robin_gated", "geo/price_aware_gated"),
          op=">=", threshold=1.2),
    Claim("autoscaling_beats_static_wh", metric="mean_energy_wh",
          ratio_of=("auto/static", "auto/autoscaled"),
          op=">=", threshold=1.2),
)


def run() -> List[Row]:
    res = sweep(GEO_BASE, {
        "router": [Option(p, router=p) for p in GEO_POLICIES],
    }, tag="geo")
    res = res.merge(sweep(AUTO_BASE, {
        "provision": [Option("static"),
                      Option("autoscaled", autoscaler="target_util")],
    }, tag="auto"))
    res.check(CLAIMS)

    rows = [Row(name=f"fleet/{label}",
                us_per_call=r.latency_p50_s * 1e6,
                derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                         + (f"gCO2/req={r.gco2_per_request_g:.4f} "
                            f"$/req={r.usd_per_request:.6f} "
                            if r.gco2_per_request_g is not None else "")
                         + (f"transitions={r.n_transitions} "
                            if r.n_transitions else "")
                         + f"p99={r.latency_p99_s:.2f}s"),
                spec_hash=r.spec_hash)
            for label, r in res.results.items()]
    rows += claim_rows(res.claims)

    # -- planet scale: one sweep point, 256 replicas, 4 regions --------
    mb, rper, nreg = 32, 64, 4
    scale_spec = ExperimentSpec(
        model="llama-3.1-8b", mode="continuous", max_batch=mb,
        replicas=rper * nreg, n_requests=N_SCALE,
        regions=[sinusoid_region(f"region{k}", phase_h=6.0 * k,
                                 replicas=rper) for k in range(nreg)],
        prompt_range=(1200, 1200), output_range=(80, 80),
        arrival="burst",
        arrival_params={"burst_size": rper * nreg * mb,
                        "burst_gap_s": 5.0})
    t0 = time.perf_counter()
    scale = sweep(scale_spec, {"router": ["round_robin"]},
                  tag="scale", cache=False)
    wall = time.perf_counter() - t0
    r = scale.results["scale/router=round_robin"]
    bound = 900.0 if N_SCALE >= 1_000_000 else 120.0
    rows.append(Row(
        "fleet/scale_256rep", wall * 1e6,
        f"{N_SCALE} req in {wall:.1f}s host ({N_SCALE / wall:.0f} req/s) "
        f"Wh/req={r.mean_energy_wh:.5f} "
        f"gCO2/req={r.gco2_per_request_g:.4f}",
        spec_hash=r.spec_hash))
    rows.append(Row(
        name="claim/fleet_scale_completes_in_minutes", us_per_call=0.0,
        derived=f"value={wall:.2f} pass={wall < bound}"))

    save_sweep("fleet", res)
    return rows
