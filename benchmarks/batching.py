"""Paper Fig. 2a/2b (energy per input/output token vs batch size) and
Fig. 6/7 (latency counterparts), on LLaMA-3.1-8B float32 static batching
— the paper's exact §4 setting — as a declarative profile-pipeline
sweep over batch size.

Each grid point profiles ``profile_seeds`` padded batches of paper-like
prompt lengths (200-4000, log-uniform) and averages the padding stats,
exactly the procedure the hand-rolled benchmark used.

Claims validated (same rows as ever, via declarative `repro.Claim`s):
* per *effective input token*: prefill rises with batch (padding waste,
  the U's right flank) while decode falls,
* per *computed input token*: prefill flat (compute-bound),
* per *output token*: monotone decrease, large-batch energy <= 70% of
  b=1 (paper: ~65% by b=16 for computed decode; log-like curve).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, sweep

BATCHES = (1, 2, 4, 8, 16)
OUT_TOKENS = 80

BASE = ExperimentSpec(pipeline="profile", model="llama-3.1-8b",
                      fmt="float32", prompt_range=(200, 4000),
                      output_range=(OUT_TOKENS, OUT_TOKENS),
                      profile_seeds=4)


def _curve(rs, metric: str) -> List[float]:
    return [rs[f"max_batch={b}"].metric(metric) for b in BATCHES]


def _monotone(rs) -> bool:
    # paper Fig 2b: per-output-token energy decreases monotonically
    out = _curve(rs, "gen_j_per_out")
    return all(a >= b * 0.98 for a, b in zip(out, out[1:]))


CLAIMS = (
    # paper Fig 2a-left: prefill J/effective-input-token RISES with
    # batch (padding waste). NOTE (EXPERIMENTS.md §Validation): the
    # paper's *decode* U-minimum at b=4 is NOT reproduced — in our
    # calibrated model the eager-stack decode remains launch/idle-
    # dominated past b=4, so its per-token energy keeps falling; the
    # padding-driven prefill rise (the U's right flank) is reproduced.
    Claim("prefill_padding_rise_per_eff_input",
          ratio_of=("max_batch=16", "max_batch=1"),
          metric="pre_j_per_eff_in", threshold=1.3),
    Claim("decode_falls_per_eff_input",
          ratio_of=("max_batch=16", "max_batch=1"),
          metric="dec_j_per_eff_in", op="<", threshold=1.0),
    Claim("prefill_flat_per_computed",
          ratio_of=("max_batch=*", "max_batch=*"),
          metric="pre_j_per_comp_in", agg="max", agg_den="min",
          op="<", threshold=1.6),
    Claim("output_tokens_monotone",
          ratio_of=("max_batch=16", "max_batch=1"),
          metric="gen_j_per_out", op="<=", threshold=1.0,
          where=_monotone),
    Claim("output_gain_by_b16",
          ratio_of=("max_batch=16", "max_batch=1"),
          metric="gen_j_per_out", op="<=", threshold=0.7),
)


def run() -> List[Row]:
    res = sweep(BASE, {"max_batch": list(BATCHES)}, claims=CLAIMS)
    rows = [Row(name=f"fig2/batch={b}",
                us_per_call=r.total_energy_j,
                derived=(f"J/eff_in={r.gen_j_per_eff_in:.4f} "
                         f"J/out={r.gen_j_per_out:.3f} "
                         f"pad={r.padding_fraction:.2f}"),
                spec_hash=r.spec_hash)
            for b in BATCHES
            for r in [res[f"max_batch={b}"]]]
    rows += claim_rows(res.claims)
    save_sweep("batching", res)
    return rows
