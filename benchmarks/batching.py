"""Paper Fig. 2a/2b (energy per input/output token vs batch size) and
Fig. 6/7 (latency counterparts), on LLaMA-3.1-8B float32 static batching
— the paper's exact §4 setting.

Claims validated:
* per *effective input token*: U-shaped (padding waste vs parallelism) —
  generate-phase minimum at small batch (paper: b=2), >=15% worse at
  b=16 than at the optimum,
* per *computed input token*: prefill flat (compute-bound), decode
  decreasing with plateau,
* per *output token*: monotone decrease, large-batch energy <= 70% of
  b=1 (paper: ~65% by b=16 for computed decode; log-like curve).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import PAPER_MODELS, Row, save_results
from repro.batching.static import pad_batch
from repro.core import PhaseProfiler, make_policy, H100_SXM
from repro.core.energy import combine

BATCHES = (1, 2, 4, 8, 16)
OUT_TOKENS = 80


def _request_lengths(batch: int, seed: int = 0) -> np.ndarray:
    """Paper-like prompt lengths 200-4000, log-uniform."""
    rng = np.random.default_rng(seed)
    return np.exp(rng.uniform(np.log(200), np.log(4000),
                              size=batch)).astype(int)


def run() -> List[Row]:
    cfg = PAPER_MODELS["llama-3.1-8b"]
    prof = PhaseProfiler(cfg, H100_SXM, make_policy("float32"))
    rows: List[Row] = []
    data = []
    for b in BATCHES:
        # average over several sampled batches for stable padding stats
        recs = []
        for seed in range(4):
            lens = _request_lengths(b, seed)
            batch = pad_batch([np.zeros(n, np.int32) for n in lens])
            s_pad = batch.tokens.shape[1]
            pre = prof.profile_prefill(b, s_pad)
            dec = prof.profile_decode(b, s_pad, OUT_TOKENS)
            gen = combine({"p": pre, "d": dec})
            eff_in = batch.effective_tokens
            comp_in = batch.computed_tokens
            out_toks = b * OUT_TOKENS
            recs.append({
                "eff_in": eff_in, "comp_in": comp_in,
                "pre_J": pre.energy_j, "dec_J": dec.energy_j,
                "gen_J": gen.energy_j,
                "pre_ms": pre.latency * 1e3, "dec_ms": dec.latency * 1e3,
                "out": out_toks,
            })
        mean = {k: float(np.mean([r[k] for r in recs])) for k in recs[0]}
        rec = {
            "batch": b,
            # Fig 2a left: energy per EFFECTIVE input token
            "pre_J_per_eff_in": mean["pre_J"] / mean["eff_in"],
            "dec_J_per_eff_in": mean["dec_J"] / mean["eff_in"],
            "gen_J_per_eff_in": mean["gen_J"] / mean["eff_in"],
            # Fig 2a right: per COMPUTED input token
            "pre_J_per_comp_in": mean["pre_J"] / mean["comp_in"],
            "dec_J_per_comp_in": mean["dec_J"] / mean["comp_in"],
            # Fig 2b: per output token
            "pre_J_per_out": mean["pre_J"] / mean["out"],
            "dec_J_per_out": mean["dec_J"] / mean["out"],
            "gen_J_per_out": mean["gen_J"] / mean["out"],
            # Fig 6/7 latency
            "pre_ms_per_comp_in": mean["pre_ms"] / mean["comp_in"],
            "dec_ms_per_out": mean["dec_ms"] / mean["out"],
            "padding_fraction": 1 - mean["eff_in"] / mean["comp_in"],
        }
        data.append(rec)
        rows.append(Row(
            name=f"fig2/batch={b}", us_per_call=mean["gen_J"],
            derived=(f"J/eff_in={rec['gen_J_per_eff_in']:.4f} "
                     f"J/out={rec['gen_J_per_out']:.3f} "
                     f"pad={rec['padding_fraction']:.2f}")))

    # paper Fig 2a-left: prefill J/effective-input-token RISES with batch
    # (padding waste). NOTE (EXPERIMENTS.md §Validation): the paper's
    # *decode* U-minimum at b=4 is NOT reproduced — in our calibrated
    # model the eager-stack decode remains launch/idle-dominated past
    # b=4, so its per-token energy keeps falling; the padding-driven
    # prefill rise (the U's right flank) is reproduced.
    pre_eff = [r["pre_J_per_eff_in"] for r in data]
    pre_rise = pre_eff[-1] / pre_eff[0]
    pre_comp = [r["pre_J_per_comp_in"] for r in data]
    pre_flat = max(pre_comp) / min(pre_comp) < 1.6
    out_curve = [r["gen_J_per_out"] for r in data]
    out_monotone = all(a >= b * 0.98 for a, b in
                       zip(out_curve, out_curve[1:]))
    out_gain = out_curve[-1] / out_curve[0]
    dec_eff = [r["dec_J_per_eff_in"] for r in data]
    checks = {
        "prefill_padding_rise_per_eff_input": (pre_rise, pre_rise >= 1.3),
        "decode_falls_per_eff_input": (dec_eff[-1] / dec_eff[0],
                                       dec_eff[-1] < dec_eff[0]),
        "prefill_flat_per_computed": (max(pre_comp) / min(pre_comp),
                                      bool(pre_flat)),
        "output_tokens_monotone": (out_gain, bool(out_monotone)),
        "output_gain_by_b16": (out_gain, out_gain <= 0.7),
    }
    for k, (v, ok) in checks.items():
        rows.append(Row(name=f"claim/{k}", us_per_call=0.0,
                        derived=f"value={v:.3f} pass={ok}"))
    save_results("batching", [{"data": data,
                               "checks": {k: [float(v), bool(ok)]
                                          for k, (v, ok)
                                          in checks.items()}}])
    return rows
