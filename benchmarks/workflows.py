"""Workflow sweep (task shape x scheduler x batch policy) over the
:class:`repro.ExperimentSpec` ``workflow=`` / ``workflow_params=`` /
``workflow_reuse=`` axes.

Production energy is increasingly billed per *task*, not per request:
RAG chains, agent loops, best-of-N sampling and speculative decoding
all issue dependent request DAGs whose orchestration — not the model —
sets the Wh/task bill. This suite serves the built-in task-graph
templates through the full engine stack and asserts the subsystem's
headline economics:

* ``agent_loop`` + prefix reuse — every round's prompt extends the
  previous round's context, so forking the parent's KV pages instead
  of re-prefilling removes the dominant prefill term: >= 1.3x lower
  Wh/task than the same workload with reuse disabled, at no-worse tail
  latency (the pinned claim of this suite),
* ``fan_out`` — best-of-N buys N candidate answers but pays for every
  one: Wh/task scales with N even though the *answer* count is one,
* ``speculative`` — the draft/verify acceptance rate decides whether
  test-time compute pays: low-acceptance drafting burns multiples of
  the high-acceptance Wh/task on the same emitted tokens,
* shape x scheduler x batch policy — every template completes all its
  tasks under every scheduler/formation combination swept (release
  composes with shaping and admission, nothing deadlocks or leaks).

Environment knobs (CI smoke / quick mode):
* ``REPRO_WORKFLOWS_NREQ`` — tasks per scenario (default 16).
"""
from __future__ import annotations

import os
from typing import List

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep

N_TASKS = int(os.environ.get("REPRO_WORKFLOWS_NREQ", "16"))

BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", max_batch=16,
                      n_requests=N_TASKS,
                      arrival="poisson",
                      arrival_params={"rate_per_s": 2.0})

#: the four built-in task-graph templates
SHAPE_AXIS = [
    Option("rag_chain", workflow="rag_chain"),
    Option("agent_loop", workflow="agent_loop",
           workflow_params={"rounds": 6}),
    Option("fan_out", workflow="fan_out"),
    Option("speculative", workflow="speculative"),
]

POLICY_AXIS = [
    Option("slot_count", batch_policy="slot_count"),
    Option("chunked", batch_policy="chunked_prefill",
           policy_params={"chunk_tokens": 512}),
]

SCHED_AXIS = [
    Option("none"),
    Option("window", scheduler="window",
           scheduler_params={"window_s": 0.5}),
]


def _all_tasks_complete(results) -> float:
    """1.0 iff every swept run completed every offered task."""
    return float(min(
        (r.n_tasks_completed == r.n_tasks) for r in results.values()))


CLAIMS = (
    # headline: KV prefix reuse on the agent loop removes the dominant
    # re-prefill term — cheaper per task at no-worse tail latency
    Claim("reuse_cuts_wh_per_task_agent_loop",
          ratio_of=("reuse/no_reuse", "reuse/reuse"),
          metric="mean_energy_per_task_wh", op=">=", threshold=1.3),
    Claim("reuse_p99_no_worse",
          ratio_of=("reuse/no_reuse", "reuse/reuse"),
          metric="latency_p99_s", op=">=", threshold=1.0),
    Claim("reuse_bills_forked_tokens",
          value_of="reuse/reuse", metric="prefix_reused_tokens",
          op=">", threshold=0.0),
    # best-of-N: the fleet pays for every candidate, the user keeps one
    Claim("fan_out_pays_per_candidate",
          ratio_of=("fanout/n8", "fanout/n2"),
          metric="mean_energy_per_task_wh", op=">", threshold=2.0),
    # speculative decoding: acceptance rate decides whether test-time
    # compute pays — low acceptance burns multiples of the Wh/task
    Claim("speculative_needs_acceptance",
          ratio_of=("spec/acc30", "spec/acc90"),
          metric="mean_energy_per_task_wh", op=">", threshold=1.5),
    # composition: every shape completes all tasks under every
    # scheduler x formation combination swept (no deadlock, no leak)
    Claim("all_tasks_complete_everywhere",
          value_fn=_all_tasks_complete, op=">=", threshold=1.0),
)


def run() -> List[Row]:
    # shape x batch policy grid
    res = sweep(BASE, {"shape": SHAPE_AXIS, "policy": POLICY_AXIS})

    # shape x scheduler (agent loop under shaping)
    res = res.merge(sweep(
        BASE.derive(workflow="agent_loop",
                    workflow_params={"rounds": 6}),
        {"sched": SCHED_AXIS}, tag="sched"))

    # the reuse ablation (pinned headline claim)
    res = res.merge(sweep(
        BASE.derive(workflow="agent_loop",
                    workflow_params={"rounds": 6}),
        {"kv": [Option("reuse"),
                Option("no_reuse", workflow_reuse=False)]},
        tag="reuse"))

    # fan-out width: answers vs Wh/task, under a loaded queue so fleet
    # idle does not dilute the per-candidate bill
    res = res.merge(sweep(
        BASE.derive(workflow="fan_out",
                    arrival_params={"rate_per_s": 6.0}),
        {"n": [Option("n2", workflow_params={"n": 2}),
               Option("n4", workflow_params={"n": 4}),
               Option("n8", workflow_params={"n": 8})]},
        tag="fanout"))

    # speculative acceptance-rate threshold
    res = res.merge(sweep(
        BASE.derive(workflow="speculative"),
        {"acc": [Option(f"acc{int(a * 100)}",
                        workflow_params={"acceptance": a})
                 for a in (0.3, 0.6, 0.9)]},
        tag="spec"))

    res.check(CLAIMS)

    rows = []
    for label, r in res.results.items():
        rows.append(Row(
            name=f"workflows/{label}",
            us_per_call=r.mean_task_latency_s * 1e6,
            derived=(f"Wh/task={r.mean_energy_per_task_wh:.5f} "
                     f"Wh/tok={r.mean_energy_per_token_wh:.6f} "
                     f"tasks={r.n_tasks_completed}/{r.n_tasks} "
                     f"crit={r.mean_task_critical_path_s:.2f}s "
                     f"p99={r.latency_p99_s:.2f}s "
                     f"reused={r.prefix_reused_tokens}"),
            spec_hash=r.spec_hash))
    rows += claim_rows(res.claims)
    save_sweep("workflows", res)
    return rows
