"""Resilience suite: failure-aware serving under injected faults.

The energy-of-failure result the fault subsystem exists to measure:
on a diurnal day served by a 4-replica fleet where every replica
takes staggered crash windows totalling ~10% downtime,

* **retry + failover completes everything** — with exponential-backoff
  retries and health-aware routing the fleet finishes 100% of the
  offered load, and its goodput (total Wh over *completed* requests)
  stays within 1.5x of the fault-free fleet's Wh/request. Faults at
  this downtime are an energy tax, not a cliff.
* **no retry strands work** — the identical schedule with resilience
  turned off leaves killed requests terminally failed: completion is
  a property of the serving policy, not of the fleet.
* **graceful drain beats hard kill** — given a spot-style preemption
  *notice*, draining (stop admitting, re-route the queue, let
  in-flight work finish) wastes >=3x less energy than killing the
  replica at the deadline with work on the wire.

Environment knobs (CI smoke / quick mode):
* ``REPRO_RESILIENCE_NREQ`` — requests in the diurnal day (default
  1200; ``--quick`` sets 400). The day shrinks with it, holding
  offered rates and the ~10% downtime fraction fixed.
"""
from __future__ import annotations

import os
from typing import List, Mapping

from benchmarks.common import Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, RunResult, sweep

N_REQ = int(os.environ.get("REPRO_RESILIENCE_NREQ", "1200"))
REPLICAS = 4
RATE_PER_S = 12.0
DAY_S = N_REQ / RATE_PER_S

#: staggered crash windows, two per replica, each 5% of the day —
#: ~10% per-replica downtime with at most one replica dark at a time
DOWNTIME_S = 0.05 * DAY_S
DAY_FAULTS = tuple(
    {"t": round(frac * DAY_S, 6), "kind": "crash", "replica": rep,
     "downtime_s": round(DOWNTIME_S, 6)}
    for rep, frac in [(0, 0.10), (1, 0.30), (2, 0.50), (3, 0.70),
                      (0, 0.62), (1, 0.82), (2, 0.22), (3, 0.42)])

_WORKLOAD = dict(model="llama-3.1-8b", fmt="bfloat16",
                 mode="continuous", max_batch=32,
                 prompt_range=(200, 4000), output_range=(10, 300))

DAY_BASE = ExperimentSpec(
    n_requests=N_REQ, replicas=REPLICAS, arrival="diurnal",
    arrival_params={"base_rate_per_s": RATE_PER_S, "period_s": DAY_S,
                    "amp_frac": 0.6}, **_WORKLOAD)

#: spot preemption with a notice window long enough to finish typical
#: in-flight work: drain re-routes the queue and lets runners finish;
#: hard kill wastes everything started after the last safe instant
N_SPOT = max(N_REQ // 4, 64)
SPOT_FAULTS = ({"t": 2.0, "kind": "preempt", "replica": 0,
                "notice_s": 8.0, "downtime_s": 20.0},)
SPOT_BASE = ExperimentSpec(
    n_requests=N_SPOT, replicas=2, arrival="poisson",
    arrival_params={"rate_per_s": 6.0, "seed": 1},
    faults=SPOT_FAULTS, retry="backoff", **_WORKLOAD)


def _goodput_ratio(results: Mapping[str, RunResult]) -> float:
    """Faulty-fleet Wh per completed request over the fault-free
    fleet's Wh/request — the energy price of surviving the faults."""
    faulty = results["day/retry"]
    return (faulty.goodput_wh_per_request
            / results["day/fault_free"].mean_energy_wh)


def _drain_waste_ratio(results: Mapping[str, RunResult]) -> float:
    """Hard-kill wasted joules over graceful-drain wasted joules
    (drain often wastes *nothing* — floor the denominator so total
    success reads as a large finite ratio, not a NaN)."""
    hard = results["spot/hard_kill"].wasted_energy_j
    drain = results["spot/drain"].wasted_energy_j
    return hard / max(drain, hard / 1e3, 1e-12)


CLAIMS = (
    Claim("retry_completes_every_request", metric="n_failed",
          value_of="day/retry", op="<=", threshold=0.0),
    Claim("retry_goodput_within_1p5x_fault_free",
          value_fn=_goodput_ratio, op="<=", threshold=1.5),
    Claim("no_retry_strands_work", metric="n_failed",
          value_of="day/no_retry", op=">", threshold=0.0),
    Claim("downtime_injection_is_real",
          value_fn=lambda rs: 1.0 - rs["day/retry"].availability,
          op=">=", threshold=0.05),
    Claim("drain_wastes_3x_less_than_hard_kill",
          value_fn=_drain_waste_ratio, op=">=", threshold=3.0),
    Claim("drain_completes_every_request", metric="n_failed",
          value_of="spot/*", agg="max", op="<=", threshold=0.0),
)


def run() -> List[Row]:
    res = sweep(DAY_BASE, {
        "resilience": [
            Option("fault_free"),
            Option("retry", faults=DAY_FAULTS, retry="backoff"),
            Option("no_retry", faults=DAY_FAULTS),
        ],
    }, tag="day")
    res = res.merge(sweep(SPOT_BASE, {
        "drain": [
            Option("drain"),
            Option("hard_kill",
                   retry_params={"drain_on_notice": False}),
        ],
    }, tag="spot"))
    res.check(CLAIMS)

    rows = []
    for label, r in res.results.items():
        derived = f"Wh/req={r.mean_energy_wh:.5f}"
        if r.n_failures is not None:
            derived += (f" failures={r.n_failures}"
                        f" retries={r.n_retries}"
                        f" failed={r.n_failed}"
                        f" wastedJ={r.wasted_energy_j:.1f}"
                        f" avail={r.availability:.4f}")
        rows.append(Row(name=f"resilience/{label}",
                        us_per_call=r.latency_p50_s * 1e6,
                        derived=derived, spec_hash=r.spec_hash))
    rows += claim_rows(res.claims)
    save_sweep("resilience", res)
    return rows
