"""Benchmark harness — one module per paper table/figure.

    fig 1a/1b + fig 4/5  -> benchmarks.precision
    fig 2a/2b + fig 6/7  -> benchmarks.batching
    fig 3a/3b/3c         -> benchmarks.serving
    batch formation      -> benchmarks.formation
    workflows / tasks    -> benchmarks.workflows
    fleet / routing      -> benchmarks.cluster
    geo / autoscale      -> benchmarks.fleet
    closed-loop control  -> benchmarks.control
    §5 scheduling        -> benchmarks.scheduler
    backends / DVFS      -> benchmarks.backend
    §6 macro estimate    -> benchmarks.macro
    simulator perf (ours)-> benchmarks.simperf
    roofline (ours, §g)  -> benchmarks.roofline_report
    CPU wall-time micro  -> benchmarks.microbench

The paper-figure suites are declarative sweeps over
:class:`repro.ExperimentSpec` (see `repro.sweep`); each prints
``name,us_per_call,derived`` CSV rows whose JSON records carry the
spec's content hash for cross-commit comparability. Claim-check rows
are named ``claim/...`` with pass/fail in the derived column; run.py
exits non-zero if any claim fails.

CLI:
    --list        print available suites and their declarative claims,
                  then exit (runs nothing)
    --only a,b    run only the named benches
    --quick       cheapest configuration (CI smoke): skips the
                  real-compute microbench and shrinks the sweeps
    --json PATH   additionally dump every row as a machine-readable
                  JSON record (one per row; claims carry pass/fail,
                  sweep rows carry their ExperimentSpec hash), so the
                  perf trajectory can be tracked across commits
    --workers N   run cache-miss sweep grid points in an N-process
                  pool (sets REPRO_SWEEP_WORKERS for every suite)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _row_record(suite: str, row) -> dict:
    """One machine-readable record per printed row (claims also carry
    their parsed value and pass/fail verdict; sweep-produced rows carry
    the spec hash of the ExperimentSpec that generated them)."""
    rec = {"suite": suite, "name": row.name,
           "us_per_call": row.us_per_call, "derived": row.derived,
           "spec_hash": getattr(row, "spec_hash", ""),
           "is_claim": row.name.startswith("claim/")}
    if rec["is_claim"]:
        for tok in row.derived.split():
            if tok.startswith("value="):
                try:
                    rec["value"] = float(tok[len("value="):])
                except ValueError:
                    pass
            elif tok.startswith("pass="):
                rec["pass"] = tok[len("pass="):] == "True"
    return rec


def _benches():
    from benchmarks import (backend, batching, cluster, control, fleet,
                            formation, macro, microbench, precision,
                            resilience, roofline_report, scheduler,
                            serving, simperf, workflows)
    return [("precision", precision),
            ("batching", batching),
            ("serving", serving),
            ("formation", formation),
            ("workflows", workflows),
            ("cluster", cluster),
            ("fleet", fleet),
            ("control", control),
            ("resilience", resilience),
            ("scheduler", scheduler),
            ("backend", backend),
            ("macro", macro),
            ("simperf", simperf),
            ("roofline", roofline_report),
            ("microbench", microbench)]


def _list_suites() -> None:
    """``--list``: the suites and the declarative claims each checks."""
    for name, mod in _benches():
        claims = getattr(mod, "CLAIMS", ())
        print(f"{name}  ({len(claims)} claims)")
        for c in claims:
            thr = (f"({c.threshold[0]}, {c.threshold[1]})"
                   if isinstance(c.threshold, tuple) else c.threshold)
            print(f"  claim/{c.name}  [{c.metric} {c.op} {thr}]")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print suites + declarative claims and exit")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    ap.add_argument("--quick", action="store_true",
                    help="cheapest/dry configuration for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all suite rows as JSON records to PATH")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="run cache-miss sweep points in an N-process "
                         "pool (default: REPRO_SWEEP_WORKERS or 1)")
    args = ap.parse_args(argv)

    if args.workers is not None:
        if args.workers < 1:
            raise SystemExit("--workers must be >= 1")
        os.environ["REPRO_SWEEP_WORKERS"] = str(args.workers)

    if args.quick:
        os.environ.setdefault("REPRO_CLUSTER_NREQ", "80")
        os.environ.setdefault("REPRO_FORMATION_NREQ", "96")
        os.environ.setdefault("REPRO_WORKFLOWS_NREQ", "8")
        os.environ.setdefault("REPRO_SCHED_NREQ", "80")
        os.environ.setdefault("REPRO_BACKEND_NREQ", "48")
        os.environ.setdefault("REPRO_SIMPERF_QUICK", "1")
        os.environ.setdefault("REPRO_MACRO_FLEET_NREQ", "20000")
        os.environ.setdefault("REPRO_FLEET_NREQ", "262144")
        os.environ.setdefault("REPRO_CONTROL_NREQ", "1400")
        os.environ.setdefault("REPRO_RESILIENCE_NREQ", "400")

    if args.list:
        _list_suites()
        return

    benches = [(n, mod.run) for n, mod in _benches()]
    if args.only:
        want = {w.strip() for w in args.only.split(",")}
        unknown = want - {n for n, _ in benches}
        if unknown:
            raise SystemExit(f"unknown benches: {sorted(unknown)}")
        benches = [(n, fn) for n, fn in benches if n in want]
    elif args.quick:    # an explicit --only selection wins over --quick
        benches = [(n, fn) for n, fn in benches if n != "microbench"]

    print("name,us_per_call,derived")
    failed = []
    records = []
    t_start = time.time()
    for name, fn in benches:
        t0 = time.perf_counter()
        rows = fn()
        for r in rows:
            print(r.csv(), flush=True)
            records.append(_row_record(name, r))
            if r.name.startswith("claim/") and "pass=False" in r.derived:
                failed.append(r.name)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)
    if args.json:
        blob = {"schema": "repro-bench-rows/v2",
                "generated_unix": t_start,
                "quick": bool(args.quick),
                "n_failed_claims": len(failed),
                "records": records}
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}",
              flush=True)
    if failed:
        print(f"# FAILED claims: {failed}", flush=True)
        sys.exit(1)
    print("# all claims pass", flush=True)


if __name__ == "__main__":
    main()
