"""Benchmark harness — one module per paper table/figure.

    fig 1a/1b + fig 4/5  -> benchmarks.precision
    fig 2a/2b + fig 6/7  -> benchmarks.batching
    fig 3a/3b/3c         -> benchmarks.serving
    fleet / routing      -> benchmarks.cluster
    §5 scheduling        -> benchmarks.scheduler
    §6 macro estimate    -> benchmarks.macro
    roofline (ours, §g)  -> benchmarks.roofline_report
    CPU wall-time micro  -> benchmarks.microbench

Prints ``name,us_per_call,derived`` CSV. Claim-check rows are named
``claim/...`` with pass/fail in the derived column; run.py exits
non-zero if any claim fails.

CLI:
    --only a,b    run only the named benches
    --quick       cheapest configuration (CI smoke): skips the
                  real-compute microbench and shrinks the sweeps
    --json PATH   additionally dump every row as a machine-readable
                  JSON record (one per row, claims carry pass/fail),
                  so the perf trajectory can be tracked across commits
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _row_record(suite: str, row) -> dict:
    """One machine-readable record per printed row (claims also carry
    their parsed value and pass/fail verdict)."""
    rec = {"suite": suite, "name": row.name,
           "us_per_call": row.us_per_call, "derived": row.derived,
           "is_claim": row.name.startswith("claim/")}
    if rec["is_claim"]:
        for tok in row.derived.split():
            if tok.startswith("value="):
                try:
                    rec["value"] = float(tok[len("value="):])
                except ValueError:
                    pass
            elif tok.startswith("pass="):
                rec["pass"] = tok[len("pass="):] == "True"
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    ap.add_argument("--quick", action="store_true",
                    help="cheapest/dry configuration for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all suite rows as JSON records to PATH")
    args = ap.parse_args(argv)

    if args.quick:
        os.environ.setdefault("REPRO_CLUSTER_NREQ", "80")
        os.environ.setdefault("REPRO_SCHED_NREQ", "80")

    from benchmarks import precision, batching, serving, cluster, \
        scheduler, macro, roofline_report, microbench
    benches = [("precision", precision.run),
               ("batching", batching.run),
               ("serving", serving.run),
               ("cluster", cluster.run),
               ("scheduler", scheduler.run),
               ("macro", macro.run),
               ("roofline", roofline_report.run),
               ("microbench", microbench.run)]
    if args.only:
        want = {w.strip() for w in args.only.split(",")}
        unknown = want - {n for n, _ in benches}
        if unknown:
            raise SystemExit(f"unknown benches: {sorted(unknown)}")
        benches = [(n, fn) for n, fn in benches if n in want]
    elif args.quick:    # an explicit --only selection wins over --quick
        benches = [(n, fn) for n, fn in benches if n != "microbench"]

    print("name,us_per_call,derived")
    failed = []
    records = []
    t_start = time.time()
    for name, fn in benches:
        t0 = time.perf_counter()
        rows = fn()
        for r in rows:
            print(r.csv(), flush=True)
            records.append(_row_record(name, r))
            if r.name.startswith("claim/") and "pass=False" in r.derived:
                failed.append(r.name)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)
    if args.json:
        blob = {"schema": "repro-bench-rows/v1",
                "generated_unix": t_start,
                "quick": bool(args.quick),
                "n_failed_claims": len(failed),
                "records": records}
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}",
              flush=True)
    if failed:
        print(f"# FAILED claims: {failed}", flush=True)
        sys.exit(1)
    print("# all claims pass", flush=True)


if __name__ == "__main__":
    main()
