"""Benchmark harness — one module per paper table/figure.

    fig 1a/1b + fig 4/5  -> benchmarks.precision
    fig 2a/2b + fig 6/7  -> benchmarks.batching
    fig 3a/3b/3c         -> benchmarks.serving
    §6 macro estimate    -> benchmarks.macro
    roofline (ours, §g)  -> benchmarks.roofline_report
    CPU wall-time micro  -> benchmarks.microbench

Prints ``name,us_per_call,derived`` CSV. Claim-check rows are named
``claim/...`` with pass/fail in the derived column; run.py exits
non-zero if any claim fails.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import precision, batching, serving, macro, \
        roofline_report, microbench
    benches = [("precision", precision.run),
               ("batching", batching.run),
               ("serving", serving.run),
               ("macro", macro.run),
               ("roofline", roofline_report.run),
               ("microbench", microbench.run)]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        t0 = time.perf_counter()
        rows = fn()
        for r in rows:
            print(r.csv(), flush=True)
            if r.name.startswith("claim/") and "pass=False" in r.derived:
                failed.append(r.name)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)
    if failed:
        print(f"# FAILED claims: {failed}", flush=True)
        sys.exit(1)
    print("# all claims pass", flush=True)


if __name__ == "__main__":
    main()
