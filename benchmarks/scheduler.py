"""Scheduler sweep (shaping/admission policy x arrival pattern x SLO
tightness) as a declarative grid over :class:`repro.ExperimentSpec`,
reproducing the paper's §5 system-level result with the active
scheduling layer (`repro.serving.scheduler`) instead of pre-shaped
arrival lists.

Claims validated (same rows as ever, via declarative `repro.Claim`s):
* window/paced shaping of a bursty stream achieves >= 10x lower mean
  Wh/request than the same unshaped stream on the naive sequential
  server, at a matched p99 latency budget,
* shaping also beats the *same* continuous engine fed the unshaped
  stream by >= 1.15x (the scheduler's own contribution),
* pacing an all-at-once burst down to the engine's best batching rate
  trends toward the paper's 100x regime (>= 35x vs naive here),
* the power-state trace accounts for >= 95% of total simulated energy,
* EDF + load shedding under overload beats passthrough on SLO
  attainment (notably the interactive tier) while keeping admitted
  requests >= 85% on-time,
* energy-budget admission control sheds mostly stragglers and cuts
  *total* energy for the same offered load (per-served-request Wh is
  the wrong metric under admission control).

Environment knobs (CI smoke / quick mode):
* ``REPRO_SCHED_NREQ`` — requests per shaping scenario (default 240).
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from benchmarks.common import RESULTS_DIR, Row, claim_rows, save_sweep
from repro import Claim, ExperimentSpec, Option, sweep
from repro.serving import (burst_arrivals, estimate_request_latency,
                           estimate_service_rate, paper_requests)

N_REQ = int(os.environ.get("REPRO_SCHED_NREQ", "240"))
#: the deadline/overload scenario needs enough offered load to actually
#: overload the engine within the interactive deadline, so it does not
#: shrink below 240 in quick mode
N_OVERLOAD = max(N_REQ, 240)
SHORT_PROMPTS = (200, 600)      # the regime where the paper's 100x lives
TIERS_TIGHT = (("interactive", 2, 2.5), ("standard", 1, 12.0),
               ("batch", 0, float("inf")))
TIERS_LOOSE = (("interactive", 2, 10.0), ("standard", 1, 60.0),
               ("batch", 0, float("inf")))

BASE = ExperimentSpec(model="llama-3.1-8b", fmt="bfloat16",
                      mode="continuous", max_batch=64, n_requests=N_REQ,
                      prompt_range=SHORT_PROMPTS)

#: the bursty, low-mean-rate stream every shaping scenario shapes
BURSTY = dict(arrival="burst",
              arrival_params={"burst_size": 20, "burst_gap_s": 6.0})

# -- straggler scenario: a burst followed by lone late requests ----------
_NB = int(N_REQ * 0.8)
_ARR_BURST = burst_arrivals(_NB, max(_NB // 5, 1), 5.0)
T_BURST_END = max(_ARR_BURST)
STRAGGLER_TIMES = tuple(list(_ARR_BURST)
                        + [T_BURST_END + 4.0 + 3.0 * i
                           for i in range(N_REQ - _NB)])


def _best_shaped(results) -> str:
    return min(("window_2s/bursty/continuous",
                "paced_30rps/bursty/continuous"),
               key=lambda k: results[k].mean_energy_wh)


def _shaped_p99_matched(results) -> bool:
    return (results[_best_shaped(results)].latency_p99_s
            <= results["unshaped/bursty/naive_sequential"].latency_p99_s)


def _int_gain(results) -> float:
    dl = results["deadline/overload/slo_tight"]
    pt = results["passthrough/overload/slo_tight"]
    return (dl.tier_attainment["interactive"]
            / max(pt.tier_attainment["interactive"], 1e-9))


def _deadline_guard(results) -> bool:
    dl = results["deadline/overload/slo_tight"]
    return (_int_gain(results) >= 1.3 and dl.n_shed > 0
            and dl.admitted_attainment >= 0.85)


def _straggler_frac(results) -> float:
    eb = results["energy_budget_10mwh/straggler/continuous"]
    if not eb.n_shed:
        return 0.0
    return sum(1 for t in eb.shed_arrival_times
               if t > T_BURST_END) / eb.n_shed


def _budget_guard(results) -> bool:
    eb = results["energy_budget_10mwh/straggler/continuous"]
    return (eb.n_shed > 0 and _straggler_frac(results) >= 0.6
            and eb.n_requests >= 0.7 * (eb.n_requests + eb.n_shed))


CLAIMS = (
    # paper §5: shaping wins >= 10x at a matched p99 budget (best of
    # the window/paced shapers vs the unshaped naive baseline)
    Claim("shaped_ge_10x_vs_unshaped_bursty",
          value_fn=lambda rs: (
              rs["unshaped/bursty/naive_sequential"].mean_energy_wh
              / rs[_best_shaped(rs)].mean_energy_wh),
          threshold=10.0, where=_shaped_p99_matched),
    # the scheduler's own contribution on one engine (consolidation +
    # planned-gap gating), beyond what continuous batching gives
    Claim("shaping_beats_unshaped_same_engine",
          ratio_of=("passthrough/bursty/continuous",
                    "window_2s/bursty/continuous"),
          threshold=1.15),
    # pacing toward the best batching rate trends toward the 100x regime
    Claim("paced_trend_toward_100x",
          ratio_of=("unshaped/burst0/naive_sequential",
                    "paced_100rps/burst0/continuous"),
          threshold=35.0),
    # acceptance: the power-state timeline accounts for the energy
    Claim("trace_accounts_ge_95pct",
          value_of="window_2s/bursty/continuous",
          metric="trace_coverage", op="range", threshold=(0.9499, 1.05)),
    Claim("deadline_protects_slo_under_overload",
          value_fn=lambda rs: (
              rs["deadline/overload/slo_tight"].slo_attainment
              - rs["passthrough/overload/slo_tight"].slo_attainment),
          threshold=0.05, where=_deadline_guard),
    # total energy over the same offered load (admission control's
    # honest metric — see module docstring)
    Claim("energy_budget_sheds_stragglers",
          ratio_of=("passthrough/straggler/continuous",
                    "energy_budget_10mwh/straggler/continuous"),
          metric="total_energy_j", threshold=1.15, where=_budget_guard),
)


def _deadline_params() -> dict:
    """Deadline-scheduler pacing from the overload workload's sampled
    mean shape (same estimate the hand-rolled benchmark used)."""
    sample = paper_requests(N_OVERLOAD, [0.0] * N_OVERLOAD, seed=3,
                            prompt_range=SHORT_PROMPTS)
    plen = int(np.mean([r.prompt_len for r in sample]))
    out = int(np.mean([r.max_new_tokens for r in sample]))
    cfg = BASE.model_config()
    return {
        "service_rate_per_s": estimate_service_rate(
            cfg, prompt_len=plen, new_tokens=out, batch=32),
        "est_latency_s": estimate_request_latency(
            cfg, prompt_len=plen, new_tokens=out, batch=32),
    }


def run() -> List[Row]:
    # -- 1. bursty low-rate stream: unshaped vs shaped ------------------
    res = sweep(BASE, {"scenario": [
        Option("unshaped/bursty/naive_sequential", mode="sequential",
               **BURSTY),
        Option("passthrough/bursty/continuous", scheduler="passthrough",
               **BURSTY),
        Option("window_2s/bursty/continuous", scheduler="window",
               scheduler_params={"window_s": 2.0}, trace=True, **BURSTY),
        Option("paced_30rps/bursty/continuous", scheduler="paced",
               scheduler_params={"rate_per_s": 30, "burst": 8}, **BURSTY),
        # -- 2. all-at-once burst paced down to the best batching rate --
        Option("unshaped/burst0/naive_sequential", mode="sequential"),
        *[Option(f"paced_{rate}rps/burst0/continuous", scheduler="paced",
                 scheduler_params={"rate_per_s": rate, "burst": 1})
          for rate in (100, 50, 20)],
        # -- 3. shaping composed with routing (cluster) -----------------
        Option("window_2s/bursty/cluster2", scheduler="window",
               scheduler_params={"window_s": 2.0}, trace=True,
               replicas=2, router="round_robin", max_batch=32, **BURSTY),
    ]})

    # -- 4. SLO tightness sweep: EDF + shedding under overload ----------
    overload = BASE.derive(n_requests=N_OVERLOAD, max_batch=32, seed=3,
                           slo_weights=(0.4, 0.4, 0.2), slo_seed=5)
    res = res.merge(sweep(overload, {
        "scheduler": [
            Option("passthrough", scheduler="passthrough"),
            Option("deadline", scheduler="deadline",
                   scheduler_params=_deadline_params()),
        ],
        "scenario": [Option("overload")],
        "slo": [Option("slo_tight", slo_tiers=TIERS_TIGHT),
                Option("slo_loose", slo_tiers=TIERS_LOOSE)],
    }))

    # -- 5. energy-budget admission: bursts + stragglers ----------------
    straggler = BASE.derive(seed=2, arrival="explicit",
                            arrival_params={"times": STRAGGLER_TIMES})
    res = res.merge(sweep(straggler, {"scheduler": [
        Option("passthrough/straggler/continuous",
               scheduler="passthrough"),
        Option("energy_budget_10mwh/straggler/continuous",
               scheduler="energy_budget",
               scheduler_params={"max_wh_per_request": 0.01}),
    ]}))
    res.check(CLAIMS)

    rows = []
    for label, r in res.results.items():
        extra = ""
        if "overload" in label:
            att_int = r.tier_attainment.get("interactive", 1.0)
            extra = f" att={r.slo_attainment:.2f} att_int={att_int:.2f}"
        if r.trace_coverage is not None:
            extra += f" trace_cov={r.trace_coverage:.3f}"
        rows.append(Row(
            name=f"sched/{label}",
            us_per_call=r.mean_latency_s * 1e6,
            derived=(f"Wh/req={r.mean_energy_wh:.5f} "
                     f"p99={r.latency_p99_s:.2f}s "
                     f"shed={r.n_shed}" + extra),
            spec_hash=r.spec_hash))
    rows += claim_rows(res.claims)

    # power-state attribution artifact (state-level timeline summary of
    # the window-shaped run; full segments via spec.run() with trace)
    win = res["window_2s/bursty/continuous"]
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "scheduler_trace.json"),
              "w") as f:
        json.dump({"spec_hash": win.spec_hash,
                   "trace_coverage": win.trace_coverage,
                   "energy_by_state_j": win.energy_by_state_j,
                   "time_by_state_s": win.time_by_state_s}, f, indent=1)
    save_sweep("scheduler", res)
    return rows
