"""Scheduler sweep: shaping/admission policy x arrival pattern x SLO
tightness, reproducing the paper's §5 system-level result with the
active scheduling layer (`repro.serving.scheduler`) instead of
pre-shaped arrival lists.

Claims validated:
* window/paced shaping of a bursty stream achieves >= 10x lower mean
  Wh/request than the same unshaped stream on the naive sequential
  server (the paper's unshaped baseline), at a matched p99 latency
  budget (shaped p99 <= unshaped p99),
* shaping also beats the *same* continuous engine fed the unshaped
  stream (the scheduler's own contribution: consolidation + planned-gap
  power gating), by >= 1.15x,
* pacing an all-at-once burst down to the engine's best batching rate
  trends toward the paper's 100x regime (>= 35x vs naive here),
* the exported power-state trace accounts for >= 95% of total simulated
  energy across prefill/decode/idle/gated segments,
* EDF + load shedding under overload beats passthrough on SLO
  attainment (notably the interactive tier) while keeping admitted
  requests >= 85% on-time,
* energy-budget admission control sheds mostly stragglers (the
  requests that cannot amortize a batch) and cuts *total* energy for
  the same offered load (per-served-request Wh is the wrong metric
  under admission control: the surviving idle tail splits across fewer
  served requests).

Environment knobs (CI smoke / quick mode):
* ``REPRO_SCHED_NREQ`` — requests per shaping scenario (default 240).
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from benchmarks.common import (PAPER_MODELS, RESULTS_DIR, Row,
                               paper_requests, save_results)
from repro.serving import (EnergyBudgetScheduler, PowerTrace, ServeEngine,
                           SLOTier, assign_slos, attainment,
                           burst_arrivals, estimate_request_latency,
                           estimate_service_rate, make_cluster,
                           make_scheduler)

N_REQ = int(os.environ.get("REPRO_SCHED_NREQ", "240"))
#: the deadline/overload scenario needs enough offered load to actually
#: overload the engine within the interactive deadline, so it does not
#: shrink below 240 in quick mode
N_OVERLOAD = max(N_REQ, 240)
SHORT_PROMPTS = (200, 600)      # the regime where the paper's 100x lives
TIERS_TIGHT = (SLOTier("interactive", 2, 2.5),
               SLOTier("standard", 1, 12.0),
               SLOTier("batch", 0, float("inf")))
TIERS_LOOSE = (SLOTier("interactive", 2, 10.0),
               SLOTier("standard", 1, 60.0),
               SLOTier("batch", 0, float("inf")))


def _engine(max_batch=64):
    return ServeEngine(PAPER_MODELS["llama-3.1-8b"], fmt="bfloat16",
                       mode="continuous", max_batch=max_batch)


def _tier_attainment(rep, tier: str) -> float:
    return attainment([r for r in rep.requests if r.slo_tier == tier],
                      [r for r in rep.shed if r.slo_tier == tier])


def run() -> List[Row]:
    cfg = PAPER_MODELS["llama-3.1-8b"]
    rows: List[Row] = []
    results = {}

    def record(name: str, rep, extra: str = "") -> None:
        s = rep.summary()
        results[name] = s
        rows.append(Row(
            name=f"sched/{name}",
            us_per_call=s["mean_latency_s"] * 1e6,
            derived=(f"Wh/req={s['mean_energy_wh']:.5f} "
                     f"p99={s['latency_p99_s']:.2f}s "
                     f"shed={s['n_shed']}" + extra)))

    def wh(name: str) -> float:
        return results[name]["mean_energy_wh"]

    # -- 1. bursty low-rate stream: unshaped vs shaped ------------------
    arr_bursty = burst_arrivals(N_REQ, 20, 6.0)

    def bursty_reqs():
        return paper_requests(N_REQ, arr_bursty, seed=0,
                              prompt_range=SHORT_PROMPTS)

    seq = ServeEngine(cfg, fmt="bfloat16", mode="sequential")
    record("unshaped/bursty/naive_sequential", seq.run(bursty_reqs()))
    record("passthrough/bursty/continuous",
           _engine().run(bursty_reqs(),
                         scheduler=make_scheduler("passthrough")))
    trace = PowerTrace()
    rep_win = _engine().run(bursty_reqs(),
                            scheduler=make_scheduler("window",
                                                     window_s=2.0),
                            trace=trace)
    record("window_2s/bursty/continuous", rep_win)
    record("paced_30rps/bursty/continuous",
           _engine().run(bursty_reqs(),
                         scheduler=make_scheduler("paced", rate_per_s=30,
                                                  burst=8)))

    # -- 2. all-at-once burst paced down to the best batching rate ------
    def burst0_reqs():
        return paper_requests(N_REQ, [0.0] * N_REQ, seed=0,
                              prompt_range=SHORT_PROMPTS)

    record("unshaped/burst0/naive_sequential", seq.run(burst0_reqs()))
    for rate in (100, 50, 20):
        record(f"paced_{rate}rps/burst0/continuous",
               _engine().run(burst0_reqs(),
                             scheduler=make_scheduler(
                                 "paced", rate_per_s=rate, burst=1)))

    # -- 3. shaping composed with routing (cluster) ---------------------
    cl_trace = PowerTrace()
    cl = make_cluster(cfg, 2, policy="round_robin", max_batch=32)
    cl_rep = cl.run(bursty_reqs(),
                    scheduler=make_scheduler("window", window_s=2.0),
                    trace=cl_trace)
    results["window_2s/bursty/cluster2"] = cl_rep.summary()
    rows.append(Row(
        name="sched/window_2s/bursty/cluster2",
        us_per_call=cl_rep.summary()["latency_p50_s"] * 1e6,
        derived=(f"Wh/req={cl_rep.mean_energy_per_request_wh:.5f} "
                 f"trace_cov={cl_trace.coverage(cl_rep.total_energy_j):.3f}")))

    # -- 4. SLO tightness sweep: EDF + shedding under overload ----------
    def overload_reqs(tiers):
        rs = paper_requests(N_OVERLOAD, [0.0] * N_OVERLOAD, seed=3,
                            prompt_range=SHORT_PROMPTS)
        return assign_slos(rs, tiers=tiers, weights=(0.4, 0.4, 0.2),
                           seed=5)

    sample = overload_reqs(TIERS_TIGHT)
    mean_plen = int(np.mean([r.prompt_len for r in sample]))
    mean_out = int(np.mean([r.max_new_tokens for r in sample]))
    svc_rate = estimate_service_rate(cfg, prompt_len=mean_plen,
                                     new_tokens=mean_out, batch=32)
    est_lat = estimate_request_latency(cfg, prompt_len=mean_plen,
                                       new_tokens=mean_out, batch=32)
    overload_reports = {}
    for tightness, tiers in (("tight", TIERS_TIGHT),
                             ("loose", TIERS_LOOSE)):
        for policy in ("passthrough", "deadline"):
            sched = (make_scheduler("passthrough")
                     if policy == "passthrough" else
                     make_scheduler("deadline", service_rate_per_s=svc_rate,
                                    est_latency_s=est_lat))
            rep = ServeEngine(cfg, fmt="bfloat16", mode="continuous",
                              max_batch=32).run(overload_reqs(tiers),
                                                scheduler=sched)
            overload_reports[(policy, tightness)] = rep
            record(f"{policy}/overload/slo_{tightness}", rep,
                   extra=(f" att={rep.slo_attainment:.2f} "
                          f"att_int="
                          f"{_tier_attainment(rep, 'interactive'):.2f}"))

    # -- 5. energy-budget admission: bursts + stragglers ----------------
    nb = int(N_REQ * 0.8)
    arr_b = burst_arrivals(nb, max(nb // 5, 1), 5.0)
    t_burst_end = max(arr_b)
    arr_s = [t_burst_end + 4.0 + 3.0 * i for i in range(N_REQ - nb)]

    def straggler_reqs():
        return paper_requests(N_REQ, list(arr_b) + arr_s, seed=2,
                              prompt_range=SHORT_PROMPTS)

    rep_pas = _engine().run(straggler_reqs(),
                            scheduler=make_scheduler("passthrough"))
    record("passthrough/straggler/continuous", rep_pas)
    budget = EnergyBudgetScheduler.for_engine(_engine(), 0.01)
    rep_eb = _engine().run(straggler_reqs(), scheduler=budget)
    shed_stragglers = sum(1 for r in rep_eb.shed
                          if r.arrival_time > t_burst_end)
    record("energy_budget_10mwh/straggler/continuous", rep_eb,
           extra=f" shed_stragglers={shed_stragglers}")

    # -- claims ---------------------------------------------------------
    naive_wh = wh("unshaped/bursty/naive_sequential")
    naive_p99 = results["unshaped/bursty/naive_sequential"]["latency_p99_s"]
    best_shaped = min(("window_2s/bursty/continuous",
                       "paced_30rps/bursty/continuous"), key=wh)
    shaped_ratio = naive_wh / wh(best_shaped)
    shaped_p99 = results[best_shaped]["latency_p99_s"]
    same_engine_ratio = (wh("passthrough/bursty/continuous")
                         / wh("window_2s/bursty/continuous"))
    trend_ratio = (wh("unshaped/burst0/naive_sequential")
                   / wh("paced_100rps/burst0/continuous"))
    cov = trace.coverage(rep_win.total_energy_j)
    dl, pt = (overload_reports[("deadline", "tight")],
              overload_reports[("passthrough", "tight")])
    adm_att = (np.mean([r.met_deadline for r in dl.requests])
               if dl.requests else 1.0)
    int_gain = (_tier_attainment(dl, "interactive")
                / max(_tier_attainment(pt, "interactive"), 1e-9))
    # total energy over the same offered load (admission control's
    # honest metric — see module docstring)
    eb_gain = rep_pas.total_energy_j / rep_eb.total_energy_j
    straggler_frac = (shed_stragglers / rep_eb.n_shed
                      if rep_eb.n_shed else 0.0)
    checks = {
        # paper §5: shaping wins >= 10x at a matched p99 budget
        "shaped_ge_10x_vs_unshaped_bursty": (
            shaped_ratio,
            shaped_ratio >= 10.0 and shaped_p99 <= naive_p99),
        # the scheduler's own contribution on one engine (consolidation
        # + planned-gap gating), beyond what continuous batching gives
        "shaping_beats_unshaped_same_engine": (
            same_engine_ratio, same_engine_ratio >= 1.15),
        # pacing toward the best batching rate trends toward the
        # paper's 100x regime
        "paced_trend_toward_100x": (trend_ratio, trend_ratio >= 35.0),
        # acceptance: the power-state timeline accounts for the energy
        "trace_accounts_ge_95pct": (cov, 0.95 <= cov <= 1.05),
        "deadline_protects_slo_under_overload": (
            dl.slo_attainment - pt.slo_attainment,
            (dl.slo_attainment >= pt.slo_attainment + 0.05
             and int_gain >= 1.3 and dl.n_shed > 0
             and adm_att >= 0.85)),
        "energy_budget_sheds_stragglers": (
            eb_gain,
            (eb_gain >= 1.15 and rep_eb.n_shed > 0
             and straggler_frac >= 0.6
             and rep_eb.n >= 0.7 * (rep_eb.n + rep_eb.n_shed))),
    }
    for k, (v, ok) in checks.items():
        rows.append(Row(name=f"claim/{k}", us_per_call=0.0,
                        derived=f"value={v:.2f} pass={ok}"))

    # power-state timeline export (the attribution artifact)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace.to_json(os.path.join(RESULTS_DIR, "scheduler_trace.json"))
    save_results("scheduler", [{"results": results,
                                "checks": {k: [float(v), bool(ok)]
                                           for k, (v, ok)
                                           in checks.items()}}])
    return rows
